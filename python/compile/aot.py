"""AOT entry point: lower every artifact in configs.default_aot_specs()
to HLO *text* plus a JSON manifest the rust coordinator loads.

HLO text — NOT `lowered.serialize()` / serialized HloModuleProto — is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
that the `xla` crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts/model.hlo.txt
(the --out path's directory receives every artifact + manifest.json; the
named file doubles as the Makefile's freshness stamp).
"""

import argparse
import hashlib
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, train_step
from .configs import AotSpec, PeftConfig
from .kernels import nf4 as nf4_k
from .kernels import paca_grad as paca_k
from .kernels import ref as kref
from .peft import trainable_param_count

DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "i8": jnp.int8}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is ESSENTIAL: the default printer
    # elides big constant literals as `constant({...})`, which the
    # xla_extension 0.5.1 text parser silently reads as ZEROS (found
    # the hard way — the NF4 codebook came back all-zero in rust).
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constant in HLO text"
    return text


def _sds(entry) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(entry.shape), DTYPES[entry.dtype])


def _entry_json(e) -> dict:
    return {"name": e.name, "shape": list(e.shape), "dtype": e.dtype,
            "role": e.role, "init": e.init, "updated": e.updated}


def lower_model_artifact(spec: AotSpec):
    cfg = configs.model(spec.model)
    pcfg = PeftConfig(method=spec.method, rank=spec.rank,
                      alpha=spec.alpha, use_pallas=spec.use_pallas)
    kind = ("vit" if spec.model.startswith("vit")
            else "cnn" if spec.model.startswith("cnn") else "lm")
    if spec.kind == "train_step":
        fn, entries, b_entries, _p0, reg = train_step.build_train_step(
            cfg, pcfg, spec.batch, spec.seq, kind=kind)
        extra = [train_step.StateEntry("lr", (), "f32", "scalar", {},
                                       False)]
        outputs = [e.name for e in entries if e.updated] + ["loss", "acc"]
    else:
        fn, entries, b_entries, _p0, reg = train_step.build_eval_step(
            cfg, pcfg, spec.batch, spec.seq, kind=kind)
        extra = []
        outputs = ["loss", "acc"]
    args = [_sds(e) for e in entries + b_entries + extra]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    row = {
        "name": spec.name, "file": f"{spec.name}.hlo.txt",
        "kind": spec.kind, "model": spec.model, "method": spec.method,
        "rank": spec.rank, "alpha": spec.alpha, "batch": spec.batch,
        "seq": spec.seq, "use_pallas": spec.use_pallas,
        "trainable_params": trainable_param_count(reg),
        "state": [_entry_json(e) for e in entries],
        "batch_inputs": [_entry_json(e) for e in b_entries],
        "extra_inputs": [_entry_json(e) for e in extra],
        "outputs": outputs,
    }
    return text, row


def lower_kernel_artifact(spec: AotSpec):
    """Kernel-level artifacts for rust-side numeric cross-checks of the
    Pallas (interpret=True) lowering."""
    if spec.name == "kernel_paca_grad":
        t, r, dout = 64, spec.rank, 64

        def fn(xp, dy):
            return (paca_k.paca_grad(xp, dy, interpret=True),)

        ins = [train_step.StateEntry("xp", (t, r), "f32", "batch", {},
                                     False),
               train_step.StateEntry("dy", (t, dout), "f32", "batch", {},
                                     False)]
        outs = ["dp"]
    elif spec.name == "kernel_nf4_roundtrip":
        # Dequant-only: quantization happens host-side (rust init.rs /
        # nf4.rs), exactly as in the production QPaCA path — the graph
        # only ever dequantizes.
        shape = (64, 64)

        def fn(codes, scales):
            return (nf4_k.dequant_weight(codes, scales, shape,
                                         interpret=True),)

        ins = [train_step.StateEntry("codes", (64, 64), "i8", "batch",
                                     {}, False),
               train_step.StateEntry("scales", (64,), "f32", "batch",
                                     {}, False)]
        outs = ["w_dequant"]
    else:
        raise KeyError(spec.name)
    lowered = jax.jit(fn).lower(*[_sds(e) for e in ins])
    text = to_hlo_text(lowered)
    row = {"name": spec.name, "file": f"{spec.name}.hlo.txt",
           "kind": "kernel", "model": spec.model, "method": spec.method,
           "rank": spec.rank, "alpha": spec.alpha, "batch": spec.batch,
           "seq": spec.seq, "use_pallas": True, "trainable_params": 0,
           "state": [], "batch_inputs": [_entry_json(e) for e in ins],
           "extra_inputs": [], "outputs": outs}
    return text, row


def lower_grad_probe(spec: AotSpec):
    """Gradient-probe graph for the Table-5 gradient-based selection:
    full-autodiff per-row gradient-norm scores of every PEFT target
    weight for one batch (the paper accumulates these over the first
    100 iterations without updating weights)."""
    cfg = configs.model(spec.model)
    pcfg = PeftConfig(method="full")
    fn_e, entries, b_entries, _p0, reg = train_step.build_eval_step(
        cfg, pcfg, spec.batch, spec.seq, kind="lm")
    import jax.numpy as jnp

    from . import model as lm
    target_names = [s.name for s in reg.specs
                    if s.name.split("/")[-1] == "w"
                    and s.name.startswith("blocks/")]
    specs_list = reg.specs

    def fn(*args):
        n = len(entries)
        params = {s.name: a for s, a in zip(specs_list, args[:n])}
        tokens = args[n]

        def loss_fn(targets):
            merged = {**params, **targets}
            return lm.loss_and_acc(merged, tokens, cfg, pcfg, None)[0]

        grads = jax.grad(loss_fn)(
            {t: params[t] for t in target_names})
        return tuple(jnp.sum(jnp.square(grads[t]), axis=1)
                     for t in target_names)

    args = [_sds(e) for e in entries + b_entries]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    row = {"name": spec.name, "file": f"{spec.name}.hlo.txt",
           "kind": "grad_probe", "model": spec.model, "method": "full",
           "rank": spec.rank, "alpha": spec.alpha, "batch": spec.batch,
           "seq": spec.seq, "use_pallas": False, "trainable_params": 0,
           "state": [_entry_json(e) for e in entries],
           "batch_inputs": [_entry_json(e) for e in b_entries],
           "extra_inputs": [],
           "outputs": [f"grad_sq/{t}" for t in target_names]}
    return text, row


def build_all(out_dir: str, only: List[str] = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for spec in configs.default_aot_specs():
        if only and spec.name not in only:
            continue
        if spec.kind == "kernel":
            text, row = lower_kernel_artifact(spec)
        elif spec.kind == "grad_probe":
            text, row = lower_grad_probe(spec)
        else:
            text, row = lower_model_artifact(spec)
        path = os.path.join(out_dir, row["file"])
        with open(path, "w") as f:
            f.write(text)
        row["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        row["bytes"] = len(text)
        rows.append(row)
        print(f"lowered {row['name']:28s} {len(text):>10d} chars")
    # --only rebuilds merge into the existing manifest instead of
    # clobbering the rows that were not rebuilt.
    mpath = os.path.join(out_dir, "manifest.json")
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        built = {r["name"] for r in rows}
        rows = [r for r in old.get("artifacts", [])
                if r["name"] not in built] + rows
        rows.sort(key=lambda r: r["name"])
    manifest = {
        "version": 1,
        "models": {name: configs.to_jsonable(m)
                   for name, m in configs.MODELS.items()},
        "artifacts": rows,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="stamp file; its dir receives all artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifact names to (re)build")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out))
    build_all(out_dir, args.only)
    # Freshness stamp for the Makefile (also a tiny smoke artifact).
    with open(args.out, "w") as f:
        f.write("# stamp: artifacts built; see manifest.json\n")
    print(f"manifest + {len(os.listdir(out_dir)) - 1} files in {out_dir}")


if __name__ == "__main__":
    main()
