"""Model / PEFT / AOT configuration presets.

The paper fine-tunes LLaMA2-7B/13B, LLaMA3-8B and LLaMA3.1-70B. Those do
not fit the CPU-PJRT testbed, so we define architecture-faithful presets
(same block structure, same 7 PEFT target matrices per block) at sizes the
testbed can train, plus *profile-only* presets mirroring the paper models
that feed the analytic device cost model (rust `simulator/`).

Every preset is exported into `artifacts/manifest.json` so the rust layer
shares a single source of truth for dimensions.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Tuple

# The seven per-block PEFT target matrices used throughout the paper
# (Appendix C: Q, K, V, O, Up, Down, Gate).
TARGET_MODULES = ("q", "k", "v", "o", "gate", "up", "down")

PEFT_METHODS = ("full", "lora", "dora", "moslora", "paca", "qlora", "qpaca")


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only LLaMA-style transformer configuration."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    # Whether this preset is only used by the analytic cost model
    # (dimensions of the paper's actual models; never lowered to HLO).
    profile_only: bool = False

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def linear_shapes(self) -> Dict[str, Tuple[int, int]]:
        """(d_in, d_out) of each PEFT target matrix in one block."""
        d, f = self.d_model, self.d_ff
        return {
            "q": (d, d),
            "k": (d, d),
            "v": (d, d),
            "o": (d, d),
            "gate": (d, f),
            "up": (d, f),
            "down": (f, d),
        }

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head + norms)."""
        per_block = sum(i * o for i, o in self.linear_shapes().values())
        per_block += 2 * self.d_model  # two RMSNorm gains
        return (
            self.vocab * self.d_model          # embedding
            + self.n_layers * per_block
            + self.d_model                     # final norm
            + self.d_model * self.vocab        # lm head
        )


@dataclass(frozen=True)
class PeftConfig:
    """Method + rank. `alpha` follows LoRA's scaling convention."""

    method: str = "paca"
    rank: int = 8
    alpha: float = 32.0
    # NF4 block size for qlora/qpaca.
    quant_block: int = 64
    # Use the Pallas kernels (interpret=True) inside the lowered graph for
    # the PaCA backward / NF4 dequant hot-spots. jnp path is numerically
    # identical (tested) and is used for the larger e2e graphs where
    # interpret-mode while-loops are impractically slow on CPU.
    use_pallas: bool = False

    def __post_init__(self):
        assert self.method in PEFT_METHODS, self.method

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


@dataclass(frozen=True)
class AotSpec:
    """One artifact to lower: (model, method, batch geometry)."""

    name: str
    model: str
    kind: str  # "train_step" | "eval_step" | "kernel"
    method: str = "paca"
    rank: int = 8
    alpha: float = 32.0
    batch: int = 8
    seq: int = 64
    use_pallas: bool = False


# --- Trainable presets (lowered to HLO, run by the rust coordinator) -----

MODELS: Dict[str, ModelConfig] = {}


def _m(cfg: ModelConfig) -> ModelConfig:
    MODELS[cfg.name] = cfg
    return cfg


# ~0.5M params; used by unit/integration tests and most benches.
TINY = _m(ModelConfig("tiny-lm", vocab=512, d_model=64, n_layers=2,
                      n_heads=4, d_ff=172, max_seq=128))
# ~5M params; table1/2-analog runs.
SMALL = _m(ModelConfig("small-lm", vocab=2048, d_model=160, n_layers=4,
                       n_heads=4, d_ff=432, max_seq=256))
# ~27M params; the end-to-end example's default.
BASE = _m(ModelConfig("base-lm", vocab=8192, d_model=320, n_layers=8,
                      n_heads=8, d_ff=864, max_seq=512))
# ~110M params; the end-to-end example (examples/e2e_train.rs).
LARGE = _m(ModelConfig("large-lm", vocab=16384, d_model=768, n_layers=12,
                       n_heads=12, d_ff=2048, max_seq=1024))

# tiny ViT / CNN for the appendix-B experiments. The CNN's dims are
# fixed in cnn.py (STAGES); the preset exists for naming/manifest only.
VIT_TINY = _m(ModelConfig("vit-tiny", vocab=0, d_model=96, n_layers=4,
                          n_heads=4, d_ff=256, max_seq=65))
CNN_TINY = _m(ModelConfig("cnn-tiny", vocab=0, d_model=96, n_layers=3,
                          n_heads=1, d_ff=96, max_seq=1))

# --- Profile-only presets: the paper's models, for the cost model --------

LLAMA2_7B = _m(ModelConfig("llama2-7b", vocab=32000, d_model=4096,
                           n_layers=32, n_heads=32, d_ff=11008,
                           max_seq=4096, profile_only=True))
LLAMA2_13B = _m(ModelConfig("llama2-13b", vocab=32000, d_model=5120,
                            n_layers=40, n_heads=40, d_ff=13824,
                            max_seq=4096, profile_only=True))
LLAMA3_8B = _m(ModelConfig("llama3-8b", vocab=128256, d_model=4096,
                           n_layers=32, n_heads=32, d_ff=14336,
                           max_seq=8192, profile_only=True))
LLAMA31_70B = _m(ModelConfig("llama3.1-70b", vocab=128256, d_model=8192,
                             n_layers=80, n_heads=64, d_ff=28672,
                             max_seq=8192, profile_only=True))


def model(name: str) -> ModelConfig:
    return MODELS[name]


# --- Artifact build list ---------------------------------------------------

def default_aot_specs() -> List[AotSpec]:
    """The artifact set `make artifacts` builds (see DESIGN.md §6)."""
    specs: List[AotSpec] = []
    for method in ("full", "lora", "dora", "moslora", "paca", "qlora",
                   "qpaca"):
        specs.append(AotSpec(
            name=f"train_{method}_tiny", model="tiny-lm", kind="train_step",
            method=method, rank=8, batch=4, seq=64,
            use_pallas=(method == "paca")))
    specs.append(AotSpec(name="train_paca_tiny_r16", model="tiny-lm",
                         kind="train_step", method="paca", rank=16,
                         batch=4, seq=64))
    specs.append(AotSpec(name="train_paca_small", model="small-lm",
                         kind="train_step", method="paca", rank=16,
                         batch=8, seq=128))
    specs.append(AotSpec(name="train_lora_small", model="small-lm",
                         kind="train_step", method="lora", rank=16,
                         batch=8, seq=128))
    specs.append(AotSpec(name="train_paca_base", model="base-lm",
                         kind="train_step", method="paca", rank=32,
                         batch=8, seq=256))
    specs.append(AotSpec(name="train_full_base", model="base-lm",
                         kind="train_step", method="full",
                         batch=8, seq=256))
    specs.append(AotSpec(name="train_paca_large", model="large-lm",
                         kind="train_step", method="paca", rank=64,
                         batch=4, seq=128))
    for mname, b, s in (("tiny-lm", 4, 64), ("small-lm", 8, 128),
                        ("base-lm", 8, 256), ("large-lm", 4, 128)):
        short = mname.split("-")[0]
        # Eval graphs take MERGED full-shape weights (method "full"),
        # so one eval artifact serves every PEFT method: the rust
        # coordinator merges adapters into the base weights first —
        # exactly the paper's inference-time merging story.
        specs.append(AotSpec(name=f"eval_lm_{short}", model=mname,
                             kind="eval_step", method="full",
                             batch=b, seq=s))
    # ViT (table 6) — lora vs paca.
    specs.append(AotSpec(name="train_paca_vit_tiny", model="vit-tiny",
                         kind="train_step", method="paca", rank=8,
                         batch=8, seq=65))
    specs.append(AotSpec(name="train_lora_vit_tiny", model="vit-tiny",
                         kind="train_step", method="lora", rank=8,
                         batch=8, seq=65))
    # CNN (table 7) — full-FT vs paca on convolutions.
    specs.append(AotSpec(name="train_paca_cnn_tiny", model="cnn-tiny",
                         kind="train_step", method="paca", rank=8,
                         batch=8, seq=1))
    specs.append(AotSpec(name="train_full_cnn_tiny", model="cnn-tiny",
                         kind="train_step", method="full",
                         batch=8, seq=1))
    # Gradient-probe for the Table-5 gradient-based selection strategy.
    specs.append(AotSpec(name="grad_probe_tiny", model="tiny-lm",
                         kind="grad_probe", batch=4, seq=64))
    # Kernel-level numeric cross-check artifacts (Pallas, interpret=True).
    specs.append(AotSpec(name="kernel_paca_grad", model="tiny-lm",
                         kind="kernel", method="paca", rank=8,
                         batch=1, seq=64, use_pallas=True))
    specs.append(AotSpec(name="kernel_nf4_roundtrip", model="tiny-lm",
                         kind="kernel", method="qpaca", rank=8,
                         batch=1, seq=64, use_pallas=True))
    return specs


def to_jsonable(cfg) -> dict:
    return asdict(cfg)
