"""PEFT parameterization correctness — the heart of the reproduction.

Key invariants:
  * PaCA's ∇P (via the custom VJP) equals the idx-rows of Full-FT's ∇W
    on the SAME model (paper §3.1: P ⊂ W, ∇P = ∇X_out ᵖX_inᵀ).
  * PaCA's backward saves only the partial activations (residual check).
  * Each method's forward matches its textbook formula.
  * Trainable-parameter counts reproduce the paper's Param-column ratios
    (PaCA r=16 ≈ LoRA r=8 params when d_out ≈ d_in... exactly 2rd_out vs
    r(d_in+d_out)).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, peft
from compile.configs import PeftConfig
from compile.kernels import ref as kref

CFG = configs.model("tiny-lm")


def _tokens(b=2, s=16, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s + 1), 0,
                              CFG.vocab)


def test_paca_grad_equals_full_grad_rows():
    """Differentiating the PaCA model wrt the dummies must give exactly
    the row-restriction of Full-FT's weight gradients."""
    pcfg_p = PeftConfig(method="paca", rank=8)
    pcfg_f = PeftConfig(method="full")
    key = jax.random.PRNGKey(0)
    params_p, reg_p = model.init_lm(key, CFG, pcfg_p)
    params_f, _ = model.init_lm(key, CFG, pcfg_f)
    toks = _tokens()

    # identical weights by construction (same key/shapes)
    np.testing.assert_array_equal(params_p["blocks/0/q/w"],
                                  params_f["blocks/0/q/w"])

    dummies = peft.paca_dummy_tree(reg_p)
    g_dum = jax.grad(
        lambda d: model.loss_and_acc(params_p, toks, CFG, pcfg_p, d)[0]
    )(dummies)
    g_full = jax.grad(
        lambda p: model.loss_and_acc({**params_f, **p}, toks, CFG,
                                     pcfg_f, None)[0]
    )({"blocks/0/q/w": params_f["blocks/0/q/w"]})

    idx = params_p["blocks/0/q/idx"]
    np.testing.assert_allclose(g_dum["blocks/0/q/w"],
                               g_full["blocks/0/q/w"][idx, :],
                               rtol=1e-4, atol=1e-5)


def test_paca_pallas_and_jnp_grad_paths_identical():
    toks = _tokens()
    outs = []
    for use_pallas in (False, True):
        pcfg = PeftConfig(method="paca", rank=8, use_pallas=use_pallas)
        params, reg = model.init_lm(jax.random.PRNGKey(0), CFG, pcfg)
        dummies = peft.paca_dummy_tree(reg)
        g = jax.grad(
            lambda d: model.loss_and_acc(params, toks, CFG, pcfg, d)[0]
        )(dummies)
        outs.append(g["blocks/1/down/w"])
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)


def test_paca_forward_is_single_gemm_no_adapter_ops():
    """PaCA's forward jaxpr must not contain adapter matmuls: the only
    dot over the q-projection input is the frozen GEMM. We check the
    jaxpr of paca_dense itself: exactly one dot_general."""
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 6))
    p = jnp.zeros((2, 6))
    idx = jnp.array([0, 3], jnp.int32)
    jaxpr = str(jax.make_jaxpr(
        lambda x, w, p, idx: peft.paca_dense(x, w, p, idx, False)
    )(x, w, p, idx))
    assert jaxpr.count("dot_general") == 1


def test_paca_residual_is_partial_activation_only():
    """The VJP residual holds x[:, idx] (T×r), not x (T×d_in)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 48))
    p = jnp.zeros((8, 48))
    idx = jnp.arange(8, dtype=jnp.int32) * 7
    _y, res = peft._paca_dense_fwd(x, w, p, idx, False)
    xp, w_res, idx_res, _shape = res
    assert xp.shape == (32, 8)          # r, not d_in
    np.testing.assert_array_equal(xp, x[:, idx])


def test_lora_forward_formula():
    pcfg = PeftConfig(method="lora", rank=4, alpha=8.0)
    reg = peft.Registry()
    params = peft.init_linear(jax.random.PRNGKey(0), reg, "l", 10, 6,
                              pcfg, 0)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 10))
    got = peft.apply_linear(params, "l", x, pcfg)
    want = kref.lora_fwd_ref(x, params["l/w"], params["l/a"],
                             params["l/b"], pcfg.scaling)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lora_b_zero_init_preserves_pretrained_output():
    for method in ("lora", "moslora", "qlora"):
        pcfg = PeftConfig(method=method, rank=4)
        reg = peft.Registry()
        params = peft.init_linear(jax.random.PRNGKey(0), reg, "l", 16, 8,
                                  pcfg, 0)
        x = jax.random.normal(jax.random.PRNGKey(4), (3, 16))
        y = peft.apply_linear(params, "l", x, pcfg)
        if method == "qlora":
            w = kref.nf4_dequantize_ref(params["l/codes"],
                                        params["l/scales"], (16, 8))
        else:
            w = params["l/w"]
        np.testing.assert_allclose(y, x @ w, rtol=1e-5, atol=1e-5)


def test_dora_init_preserves_pretrained_output():
    """DoRA at init: mag = ||W||_col and B = 0 → output == x @ W."""
    pcfg = PeftConfig(method="dora", rank=4)
    reg = peft.Registry()
    params = peft.init_linear(jax.random.PRNGKey(0), reg, "l", 12, 7,
                              pcfg, 0)
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 12))
    y = peft.apply_linear(params, "l", x, pcfg)
    np.testing.assert_allclose(y, x @ params["l/w"], rtol=1e-3, atol=1e-4)


def test_qpaca_forward_uses_fp_rows_over_quantized_base():
    pcfg = PeftConfig(method="qpaca", rank=4)
    reg = peft.Registry()
    params = peft.init_linear(jax.random.PRNGKey(0), reg, "l", 16, 8,
                              pcfg, 0)
    x = jax.random.normal(jax.random.PRNGKey(6), (3, 16))
    y = peft.apply_linear(params, "l", x, pcfg)
    w = kref.nf4_dequantize_ref(params["l/codes"], params["l/scales"],
                                (16, 8))
    w = w.at[params["l/idx"], :].set(params["l/p"])
    np.testing.assert_allclose(y, x @ w, rtol=1e-5, atol=1e-5)


def test_qpaca_grad_matches_row_restriction():
    pcfg = PeftConfig(method="qpaca", rank=4)
    reg = peft.Registry()
    params = peft.init_linear(jax.random.PRNGKey(0), reg, "l", 16, 8,
                              pcfg, 0)
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 16))
    dyw = jax.random.normal(jax.random.PRNGKey(8), (5, 8))

    def loss(p):
        y = peft.apply_linear({**params, "l/p": p}, "l", x, pcfg)
        return jnp.sum(y * dyw)

    dp = jax.grad(loss)(params["l/p"])
    np.testing.assert_allclose(dp, x[:, params["l/idx"]].T @ dyw,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("method,rank", [("lora", 8), ("paca", 8),
                                         ("paca", 16), ("dora", 8),
                                         ("moslora", 8)])
def test_trainable_param_counts(method, rank):
    """Paper Table 1: PaCA r=16 has ~the same trainable params as LoRA
    r=8 on square-ish targets; PaCA r=8 has about half."""
    pcfg = PeftConfig(method=method, rank=rank)
    _params, reg = model.init_lm(jax.random.PRNGKey(0), CFG, pcfg)
    n = peft.trainable_param_count(reg)
    shapes = CFG.linear_shapes()
    per_block = 0
    for d_in, d_out in shapes.values():
        if method == "paca":
            per_block += rank * d_out
        elif method in ("lora", "moslora", "dora"):
            per_block += rank * (d_in + d_out)
            if method == "moslora":
                per_block += rank * rank
            if method == "dora":
                per_block += d_out
    assert n == CFG.n_layers * per_block


def test_index_selection_no_replacement():
    pcfg = PeftConfig(method="paca", rank=16)
    params, _ = model.init_lm(jax.random.PRNGKey(0), CFG, pcfg)
    for L in range(CFG.n_layers):
        for t in configs.TARGET_MODULES:
            idx = np.asarray(params[f"blocks/{L}/{t}/idx"])
            assert len(np.unique(idx)) == len(idx)
            d_in = CFG.linear_shapes()[t][0]
            assert idx.min() >= 0 and idx.max() < d_in
