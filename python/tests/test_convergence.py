"""Theorem 1 (paper §3.2 / Appendix A): with L-Lipschitz gradients and
0 < η < 2/L, updating ONLY a random subset of weight columns strictly
decreases the loss by at least η(1 − ηL/2)·‖∇P‖² per step.

We verify the bound exactly on quadratics (where L is known in closed
form), verify divergence when η > 2/L is violated badly, and verify
empirical convergence of PaCA-SGD on a small MLP (the paper's own
fallback argument for non-Lipschitz nets).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st


def _quadratic(seed, d):
    """f(w) = 0.5 wᵀ A w − bᵀw with A ≻ 0; L = λ_max(A)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    m = jax.random.normal(k1, (d, d))
    a = m @ m.T / d + 0.1 * jnp.eye(d)
    b = jax.random.normal(k2, (d,))
    lip = float(jnp.linalg.eigvalsh(a)[-1])

    def f(w):
        return 0.5 * w @ a @ w - b @ w

    return f, lip


@given(seed=st.integers(0, 1000), d=st.integers(4, 24),
       eta_frac=st.floats(0.05, 0.95), data=st.data())
@settings(max_examples=30)
def test_theorem1_descent_bound_on_quadratics(seed, d, eta_frac, data):
    r = data.draw(st.integers(1, d))
    f, lip = _quadratic(seed, d)
    eta = eta_frac * 2.0 / lip
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))
    idx = np.asarray(jax.random.permutation(
        jax.random.PRNGKey(seed + 2), d)[:r])
    g = jax.grad(f)(w)
    gp = jnp.zeros_like(g).at[idx].set(g[idx])       # ∇P (padded)
    w_new = w - eta * gp                             # paper Eq. 11
    lhs = float(f(w_new))
    bound = float(f(w) - eta * (1 - eta * lip / 2.0)
                  * jnp.sum(g[idx] ** 2))
    assert lhs <= bound + 1e-4 * (1 + abs(bound))


def test_theorem1_violated_lr_diverges():
    f, lip = _quadratic(0, 8)
    w = jax.random.normal(jax.random.PRNGKey(3), (8,))
    eta = 4.0 / lip  # > 2/L
    idx = np.arange(8)  # full update — worst case
    vals = []
    for _ in range(40):
        g = jax.grad(f)(w)
        w = w - eta * jnp.zeros_like(g).at[idx].set(g[idx])
        vals.append(float(f(w)))
    assert vals[-1] > vals[0]


def test_paca_sgd_converges_on_quadratic_to_subspace_optimum():
    """With a FIXED random subset, PaCA-SGD must reach the minimizer of
    f restricted to the subspace {w: w_j = w0_j ∀ j ∉ idx}."""
    f, lip = _quadratic(7, 12)
    w0 = jax.random.normal(jax.random.PRNGKey(8), (12,))
    idx = np.asarray(jax.random.permutation(jax.random.PRNGKey(9),
                                            12)[:5])
    w = w0
    eta = 1.0 / lip
    for _ in range(800):
        g = jax.grad(f)(w)
        w = w.at[idx].add(-eta * g[idx])
    g_final = jax.grad(f)(w)
    # First-order optimality *within the subspace*.
    assert float(jnp.abs(g_final[idx]).max()) < 1e-4
    # Untouched coordinates stayed exactly at w0.
    mask = np.ones(12, bool)
    mask[idx] = False
    np.testing.assert_array_equal(np.asarray(w)[mask],
                                  np.asarray(w0)[mask])


def test_paca_converges_on_mlp_regression():
    """Empirical §3.2-style check on a 2-layer MLP: training 25% of the
    columns of each weight drives the loss down monotonically (averaged)
    and by a large factor."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w1 = jax.random.normal(k1, (16, 64)) * 0.3
    w2 = jax.random.normal(k2, (64, 1)) * 0.3
    x = jax.random.normal(k3, (256, 16))
    y = jnp.sin(x.sum(axis=1, keepdims=True))
    idx1 = np.asarray(jax.random.permutation(k4, 16)[:4])
    idx2 = np.asarray(jax.random.permutation(k4, 64)[:16])

    def loss(w1, w2):
        h = jnp.tanh(x @ w1)
        return jnp.mean((h @ w2 - y) ** 2)

    l0 = float(loss(w1, w2))
    grad_fn = jax.jit(jax.grad(loss, argnums=(0, 1)))
    for _ in range(300):
        g1, g2 = grad_fn(w1, w2)
        w1 = w1.at[idx1, :].add(-0.05 * g1[idx1, :])
        w2 = w2.at[idx2, :].add(-0.05 * g2[idx2, :])
    l1 = float(loss(w1, w2))
    assert l1 < 0.25 * l0, (l0, l1)


def test_partial_update_norm_never_exceeds_full():
    """‖∇P‖ ≤ ‖∇W‖ — the descent quantity in Thm 1 is a sub-norm."""
    f, _ = _quadratic(11, 20)
    w = jax.random.normal(jax.random.PRNGKey(12), (20,))
    g = np.asarray(jax.grad(f)(w))
    for r in (1, 5, 10, 20):
        idx = np.random.RandomState(r).permutation(20)[:r]
        assert np.linalg.norm(g[idx]) <= np.linalg.norm(g) + 1e-9
