"""Manifest/artifact contract tests (the L2↔L3 interface). Runs against
the artifacts built by `make artifacts` when present; otherwise builds a
minimal subset into a temp dir."""

import json
import os

import pytest

from compile import aot, configs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest(tmp_path_factory):
    path = os.path.join(ART, "manifest.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = tmp_path_factory.mktemp("art")
    return aot.build_all(str(out), only=["train_paca_tiny",
                                         "eval_lm_tiny",
                                         "kernel_paca_grad"])


def test_manifest_has_every_default_spec_or_subset(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    assert "train_paca_tiny" in names


def test_artifact_rows_well_formed(manifest):
    for a in manifest["artifacts"]:
        assert a["file"].endswith(".hlo.txt")
        seen = set()
        for e in a["state"] + a["batch_inputs"] + a["extra_inputs"]:
            assert e["name"] not in seen
            seen.add(e["name"])
            assert all(d > 0 for d in e["shape"]) or e["shape"] == []
            assert e["dtype"] in ("f32", "i32", "i8")
        if a["kind"] == "train_step":
            updated = [e["name"] for e in a["state"] if e["updated"]]
            assert a["outputs"] == updated + ["loss", "acc"]
            assert a["outputs"][-2:] == ["loss", "acc"]
            assert a["trainable_params"] > 0


def test_state_roles_valid(manifest):
    valid = {"trainable", "paca_w", "frozen", "index", "opt_m", "opt_v",
             "opt_step"}
    for a in manifest["artifacts"]:
        for e in a["state"]:
            assert e["role"] in valid, e


def test_init_kinds_are_known(manifest):
    known = {"normal", "zeros", "ones", "eye", "choice", "col_norm",
             "nf4_codes", "nf4_scales", "rows_of", "const_i32"}
    for a in manifest["artifacts"]:
        for e in a["state"]:
            assert e["init"]["kind"] in known, e


def test_paca_artifacts_have_row_sliced_moments(manifest):
    for a in manifest["artifacts"]:
        if a["method"] != "paca" or a["kind"] != "train_step":
            continue
        rank = a["rank"]
        by_name = {e["name"]: e for e in a["state"]}
        for name, e in by_name.items():
            if e["role"] == "paca_w":
                m = by_name["opt/m/" + name]
                # rank clamps to the selected axis (e.g. a conv stage
                # with only 3 input channels); trailing dims match W.
                assert m["shape"][0] == min(rank, e["shape"][0])
                assert m["shape"][1:] == e["shape"][1:]


def test_models_section_includes_profiles(manifest):
    ms = manifest["models"]
    assert "llama3-8b" in ms and ms["llama3-8b"]["profile_only"]
    assert "tiny-lm" in ms and not ms["tiny-lm"]["profile_only"]


def test_hlo_files_exist_and_parse_header(manifest):
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        pytest.skip("full artifact dir not built")
    for a in manifest["artifacts"]:
        p = os.path.join(ART, a["file"])
        assert os.path.exists(p), p
        with open(p) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), a["file"]
