"""LoRA two-GEMM adapter kernel + RMSNorm kernel vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import lora as lora_k
from compile.kernels import ref as kref
from compile.kernels import rmsnorm as rms_k


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             dtype=jnp.float32)


@given(t=st.integers(1, 200), k=st.integers(1, 150), n=st.integers(1, 150))
def test_tiled_matmul(t, k, n):
    x, w = _rand(0, t, k), _rand(1, k, n)
    np.testing.assert_allclose(lora_k.matmul(x, w), x @ w,
                               rtol=1e-4, atol=1e-4)


@given(t=st.integers(1, 128), din=st.integers(1, 100),
       dout=st.integers(1, 100), r=st.integers(1, 32))
def test_lora_fwd(t, din, dout, r):
    x = _rand(2, t, din)
    w = _rand(3, din, dout)
    a = _rand(4, din, r)
    b = _rand(5, r, dout)
    got = lora_k.lora_fwd(x, w, a, b, scaling=0.5)
    want = kref.lora_fwd_ref(x, w, a, b, 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_lora_zero_b_is_identity_path():
    """At init B = 0, so LoRA's forward equals the frozen model's."""
    x, w, a = _rand(6, 32, 24), _rand(7, 24, 16), _rand(8, 24, 4)
    got = lora_k.lora_fwd(x, w, a, jnp.zeros((4, 16)), scaling=2.0)
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


def test_lora_adapter_is_two_serialized_calls():
    """Structural check: the adapter path goes through two pallas_call
    invocations (the serialization the paper measures); the jaxpr must
    contain two separate pallas-derived calls."""
    x, a, b = _rand(9, 16, 12), _rand(10, 12, 4), _rand(11, 4, 8)
    jaxpr = str(jax.make_jaxpr(
        lambda x, a, b: lora_k.lora_adapter(x, a, b, 1.0))(x, a, b))
    assert jaxpr.count("pallas_call") >= 2


@given(t=st.integers(1, 300), d=st.integers(1, 256))
def test_rmsnorm(t, d):
    x, g = _rand(12, t, d), _rand(13, d)
    np.testing.assert_allclose(rms_k.rmsnorm(x, g),
                               kref.rmsnorm_ref(x, g),
                               rtol=1e-4, atol=1e-4)


def test_rmsnorm_scale_invariance():
    """RMSNorm(c·x) == RMSNorm(x) for c > 0."""
    x, g = _rand(14, 8, 32), jnp.ones(32)
    a = rms_k.rmsnorm(x, g)
    b = rms_k.rmsnorm(3.7 * x, g)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
