"""NF4 quantization (QLoRA/QPaCA substrate): codebook properties,
quantize/dequantize round-trip error bounds, Pallas dequant vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import nf4 as nf4_k
from compile.kernels import ref as kref


def test_codebook_is_sorted_symmetric_16():
    cb = np.asarray(kref.NF4_CODEBOOK)
    assert cb.shape == (16,)
    assert np.all(np.diff(cb) > 0)
    assert cb[0] == -1.0 and cb[-1] == 1.0
    assert cb[7] == 0.0  # exact-zero representation


@given(nblk=st.integers(1, 40), seed=st.integers(0, 2**30))
def test_dequant_kernel_matches_ref(nblk, seed):
    k = jax.random.PRNGKey(seed)
    codes = jax.random.randint(k, (nblk, 64), 0, 16).astype(jnp.int8)
    scales = jnp.abs(jax.random.normal(k, (nblk,))) + 0.01
    got = nf4_k.nf4_dequantize(codes, scales)
    want = kref.NF4_CODEBOOK[codes.astype(jnp.int32)] * scales[:, None]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**30))
def test_roundtrip_error_bounded_by_half_code_gap(seed):
    """|w - dq(q(w))| <= scale * max_gap/2 per block."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (8, 64)) * 0.05
    codes, scales = kref.nf4_quantize_ref(w)
    deq = nf4_k.dequant_weight(codes, scales, w.shape)
    cb = np.asarray(kref.NF4_CODEBOOK)
    max_gap = np.max(np.diff(cb))
    bound = np.asarray(scales)[:, None] * (max_gap / 2) + 1e-7
    err = np.abs(np.asarray(w).reshape(-1, 64) -
                 np.asarray(deq).reshape(-1, 64))
    assert np.all(err <= bound)


def test_roundtrip_idempotent():
    """Quantizing an already-quantized tensor is exact."""
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    c1, s1 = kref.nf4_quantize_ref(w)
    d1 = kref.nf4_dequantize_ref(c1, s1, w.shape)
    c2, s2 = kref.nf4_quantize_ref(d1)
    d2 = kref.nf4_dequantize_ref(c2, s2, w.shape)
    np.testing.assert_allclose(d1, d2, rtol=1e-6, atol=1e-6)


def test_zero_block_stays_zero():
    w = jnp.zeros((2, 64))
    codes, scales = kref.nf4_quantize_ref(w)
    deq = kref.nf4_dequantize_ref(codes, scales, w.shape)
    np.testing.assert_array_equal(deq, w)


def test_absmax_is_exactly_representable():
    """The element with the block's max |w| maps to ±1 * scale = itself."""
    w = jnp.zeros((1, 64)).at[0, 5].set(0.37).at[0, 9].set(-0.1)
    codes, scales = kref.nf4_quantize_ref(w)
    deq = kref.nf4_dequantize_ref(codes, scales, w.shape)
    assert abs(float(deq[0, 5]) - 0.37) < 1e-7


def test_quantized_memory_ratio():
    """4-bit codes + one f32 scale per 64 weights ≈ 4.5 bits/weight —
    the Table-3 memory claim's substrate."""
    d_in, d_out = 256, 256
    n = d_in * d_out
    bits = n * 4 + (n // 64) * 32
    assert bits / n == pytest.approx(4.5)
