"""CNN substrate (paper Table 7): conv PaCA = input-channel selection.
The custom VJP's ∇P must equal the channel-restriction of the full conv
weight gradient, and only selected channels may train."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import cnn, configs, train_step
from compile.configs import PeftConfig

CFG = configs.model("cnn-tiny")


def test_paca_conv_grad_is_channel_restriction():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 3, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 3, 3)) * 0.3
    idx = jnp.array([0, 2], jnp.int32)
    dy_w = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 8, 8))

    def loss_paca(p):
        y = cnn.paca_conv(x, w, p, idx)
        return jnp.sum(y * dy_w)

    dp = jax.grad(loss_paca)(jnp.zeros((2, 5, 3, 3)))

    def loss_full(w_):
        return jnp.sum(cnn.conv(x, w_) * dy_w)

    dw_full = jax.grad(loss_full)(w)
    np.testing.assert_allclose(dp, dw_full[idx], rtol=1e-4, atol=1e-4)


def test_paca_conv_dx_matches_full():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 3, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 4, 3, 3)) * 0.3
    idx = jnp.array([1], jnp.int32)
    dy_w = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 8, 8))
    dx_paca = jax.grad(lambda x_: jnp.sum(
        cnn.paca_conv(x_, w, jnp.zeros((1, 4, 3, 3)), idx) * dy_w))(x)
    dx_full = jax.grad(lambda x_: jnp.sum(cnn.conv(x_, w) * dy_w))(x)
    np.testing.assert_allclose(dx_paca, dx_full, rtol=1e-4, atol=1e-4)


def test_cnn_forward_shape_and_pool():
    pcfg = PeftConfig(method="paca", rank=8)
    params, _reg = cnn.init_cnn(jax.random.PRNGKey(0), CFG, pcfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 32))
    logits = cnn.forward(params, imgs, pcfg)
    assert logits.shape == (3, cnn.N_CLASSES)
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 1, 4, 4)
    p = cnn.pool2(x)
    assert p.shape == (1, 1, 2, 2)
    assert float(p[0, 0, 0, 0]) == pytest.approx((0 + 1 + 4 + 5) / 4)


def test_cnn_train_only_selected_channels_change():
    pcfg = PeftConfig(method="paca", rank=2)
    fn, entries, _b, p0, _reg = train_step.build_train_step(
        CFG, pcfg, batch=4, seq=1, kind="cnn")
    state = train_step.initial_state(entries, p0)
    imgs = jax.random.normal(jax.random.PRNGKey(2), (4, 3, 32, 32))
    labels = jax.random.randint(jax.random.PRNGKey(3), (4,), 0, 10)
    jfn = jax.jit(fn)
    upd = [e for e in entries if e.updated]
    n2i = {e.name: i for i, e in enumerate(entries)}
    outs = jfn(*state, imgs, labels, jnp.float32(1e-2))
    new = dict(zip([e.name for e in upd], outs[:len(upd)]))
    w0 = np.asarray(p0["convs/0/w"])
    w1 = np.asarray(new["convs/0/w"])
    idx = np.asarray(p0["convs/0/idx"])
    changed = np.any(w0 != w1, axis=(1, 2, 3))
    for c in range(w0.shape[0]):
        assert changed[c] == (c in idx), (c, idx)


def test_cnn_paca_rank_clamped_to_channels():
    """Stage 0 has only 3 input channels; rank 8 must clamp to 3."""
    pcfg = PeftConfig(method="paca", rank=8)
    _params, reg = cnn.init_cnn(jax.random.PRNGKey(0), CFG, pcfg)
    spec = next(s for s in reg.specs if s.name == "convs/0/idx")
    assert spec.shape == (3,)
    spec2 = next(s for s in reg.specs if s.name == "convs/1/idx")
    assert spec2.shape == (8,)
