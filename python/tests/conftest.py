import jax
from hypothesis import HealthCheck, settings

jax.config.update("jax_platform_name", "cpu")

# Pallas interpret-mode + jit compile times dominate; disable deadlines.
settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[HealthCheck.too_slow,
                           HealthCheck.data_too_large])
settings.load_profile("kernels")
