"""LM substrate checks: shapes, causality, RoPE, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.configs import PeftConfig

CFG = configs.model("tiny-lm")
PCFG = PeftConfig(method="paca", rank=8)


def _setup(method="paca"):
    pcfg = PeftConfig(method=method, rank=8)
    params, reg = model.init_lm(jax.random.PRNGKey(0), CFG, pcfg)
    return params, reg, pcfg


def test_logits_shape():
    params, _reg, pcfg = _setup()
    toks = jnp.zeros((3, 20), jnp.int32)
    logits = model.forward(params, toks, CFG, pcfg)
    assert logits.shape == (3, 20, CFG.vocab)


def test_causality():
    """Changing a future token must not change past logits."""
    params, _reg, pcfg = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              CFG.vocab)
    toks2 = toks.at[0, 10].set((toks[0, 10] + 1) % CFG.vocab)
    l1 = model.forward(params, toks, CFG, pcfg)
    l2 = model.forward(params, toks2, CFG, pcfg)
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], rtol=1e-4,
                               atol=1e-5)
    assert not np.allclose(l1[0, 10:], l2[0, 10:], atol=1e-5)


def test_rope_tables_orthonormal_rotation():
    cos, sin = model.rope_tables(32, 16)
    np.testing.assert_allclose(np.asarray(cos) ** 2 + np.asarray(sin) ** 2,
                               np.ones((32, 8)), rtol=1e-6)
    # position 0 is the identity rotation
    np.testing.assert_allclose(np.asarray(cos)[0], np.ones(8))
    np.testing.assert_allclose(np.asarray(sin)[0], np.zeros(8))


def test_rope_preserves_norm():
    cos, sin = model.rope_tables(8, 16)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 8, 16))
    xr = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(xr, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_shift_property():
    """RoPE inner products depend only on relative position: the (q·k)
    of tokens (i, j) with identical content equals that of (i+s, j+s)."""
    cos, sin = model.rope_tables(64, 16)
    q = jax.random.normal(jax.random.PRNGKey(3), (16,))
    k = jax.random.normal(jax.random.PRNGKey(4), (16,))

    def rot(v, pos):
        vv = v.reshape(1, 1, 1, 16)
        return model.apply_rope(vv, cos[pos:pos + 1], sin[pos:pos + 1]) \
            .reshape(16)

    d1 = float(rot(q, 5) @ rot(k, 3))
    d2 = float(rot(q, 25) @ rot(k, 23))
    assert d1 == pytest.approx(d2, rel=1e-4)


def test_forward_deterministic():
    params, _reg, pcfg = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0,
                              CFG.vocab)
    l1 = model.forward(params, toks, CFG, pcfg)
    l2 = model.forward(params, toks, CFG, pcfg)
    np.testing.assert_array_equal(l1, l2)


@pytest.mark.parametrize("method", ["full", "lora", "paca"])
def test_loss_close_to_uniform_at_init(method):
    """Head weights are ~N(0, 0.02²) ⇒ initial loss ≈ ln(V)."""
    params, _reg, pcfg = _setup(method)
    toks = jax.random.randint(jax.random.PRNGKey(6), (4, 33), 0,
                              CFG.vocab)
    loss, acc = model.loss_and_acc(params, toks, CFG, pcfg)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5
    assert 0.0 <= float(acc) <= 0.05


def test_param_counts_match_config_formula():
    params, _reg, pcfg = _setup("full")
    n = sum(int(np.prod(p.shape)) for p in params.values())
    assert n == CFG.n_params()


def test_profile_models_param_counts_sane():
    """The profile-only presets should land near the advertised sizes.
    Tolerance 15%: our presets use MHA while LLaMA3 uses GQA (smaller
    K/V projections), which the cost model does not need to distinguish
    — PEFT adapters attach to the same seven matrices either way."""
    assert configs.model("llama3-8b").n_params() == \
        pytest.approx(8.0e9, rel=0.15)
    assert configs.model("llama2-7b").n_params() == \
        pytest.approx(6.7e9, rel=0.08)
    assert configs.model("llama3.1-70b").n_params() == \
        pytest.approx(70e9, rel=0.15)
