"""Train-step graph invariants for every method (the L2↔L3 contract):
loss decreases, frozen tensors never change, PaCA touches only the
selected rows, the updated-outputs list matches the manifest convention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, train_step
from compile.configs import PeftConfig

CFG = configs.model("tiny-lm")
METHODS = ["full", "lora", "dora", "moslora", "paca", "qlora", "qpaca"]


def _run(method, steps=4, rank=8, lr=1e-3, use_pallas=False):
    pcfg = PeftConfig(method=method, rank=rank, use_pallas=use_pallas)
    fn, entries, b_ents, p0, reg = train_step.build_train_step(
        CFG, pcfg, batch=2, seq=16)
    state = train_step.initial_state(entries, p0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              CFG.vocab)
    jfn = jax.jit(fn)
    upd = [e for e in entries if e.updated]
    n2i = {e.name: i for i, e in enumerate(entries)}
    losses = []
    for _ in range(steps):
        outs = jfn(*state, toks, jnp.float32(lr))
        for j, e in enumerate(upd):
            state[n2i[e.name]] = outs[j]
        losses.append(float(outs[-2]))
    return losses, state, entries, p0, reg


@pytest.mark.parametrize("method", METHODS)
def test_loss_decreases(method):
    losses, *_ = _run(method)
    assert losses[-1] < losses[0], (method, losses)


def test_paca_only_selected_rows_change():
    _losses, state, entries, p0, _reg = _run("paca", steps=3)
    n2i = {e.name: i for i, e in enumerate(entries)}
    for L in range(CFG.n_layers):
        name = f"blocks/{L}/q/w"
        w0 = np.asarray(p0[name])
        w1 = np.asarray(state[n2i[name]])
        idx = np.asarray(p0[f"blocks/{L}/q/idx"])
        changed = np.any(w0 != w1, axis=1)
        assert changed[idx].all(), "selected rows must train"
        mask = np.ones(w0.shape[0], bool)
        mask[idx] = False
        np.testing.assert_array_equal(w0[mask], w1[mask])


@pytest.mark.parametrize("method", ["lora", "paca", "qpaca"])
def test_frozen_entries_not_in_outputs(method):
    pcfg = PeftConfig(method=method, rank=8)
    _fn, entries, _b, _p0, _reg = train_step.build_train_step(
        CFG, pcfg, batch=2, seq=16)
    for e in entries:
        if e.role in ("frozen", "index"):
            assert not e.updated
        if e.role in ("trainable", "paca_w", "opt_m", "opt_v",
                      "opt_step"):
            assert e.updated


def test_lora_frozen_weight_unchanged_after_steps():
    _losses, state, entries, p0, _ = _run("lora", steps=3)
    n2i = {e.name: i for i, e in enumerate(entries)}
    name = "blocks/0/up/w"
    np.testing.assert_array_equal(np.asarray(p0[name]),
                                  np.asarray(state[n2i[name]]))


def test_step_counter_increments():
    _losses, state, entries, _p0, _ = _run("paca", steps=3)
    n2i = {e.name: i for i, e in enumerate(entries)}
    assert int(state[n2i["opt/step"]]) == 4  # starts at 1, 3 steps


def test_paca_pallas_graph_matches_jnp_graph():
    """One full train step with the Pallas ∇P kernel vs the jnp path —
    identical updated weights (the artifacts use the Pallas path)."""
    l_jnp, s_jnp, entries, _p0, _ = _run("paca", steps=2,
                                         use_pallas=False)
    l_pal, s_pal, _, _, _ = _run("paca", steps=2, use_pallas=True)
    assert l_jnp == pytest.approx(l_pal, rel=1e-5)
    n2i = {e.name: i for i, e in enumerate(entries)}
    i = n2i["blocks/0/gate/w"]
    np.testing.assert_allclose(np.asarray(s_jnp[i]),
                               np.asarray(s_pal[i]), rtol=1e-5,
                               atol=1e-6)


def test_state_entry_layout_matches_manifest_convention():
    """params first (registry order), then opt/m/*, opt/v/*, opt/step."""
    pcfg = PeftConfig(method="paca", rank=8)
    _fn, entries, _b, _p0, reg = train_step.build_train_step(
        CFG, pcfg, batch=2, seq=16)
    n_params = len(reg.specs)
    assert [e.name for e in entries[:n_params]] == \
        [s.name for s in reg.specs]
    ms = [e for e in entries if e.role == "opt_m"]
    vs = [e for e in entries if e.role == "opt_v"]
    assert len(ms) == len(vs) > 0
    assert entries[-1].name == "opt/step"
    # PaCA moments are row-sliced (r, d_out), not full weight shape.
    m_q = next(e for e in ms if e.name == "opt/m/blocks/0/q/w")
    assert m_q.shape == (8, CFG.d_model)


def test_eval_step_runs_and_matches_trainstep_loss_at_init():
    pcfg = PeftConfig(method="paca", rank=8)
    fn_t, entries, _b, p0, _ = train_step.build_train_step(
        CFG, pcfg, batch=2, seq=16)
    fn_e, e_entries, _be, p0e, _ = train_step.build_eval_step(
        CFG, pcfg, batch=2, seq=16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 17), 0,
                              CFG.vocab)
    state = train_step.initial_state(entries, p0)
    outs = jax.jit(fn_t)(*state, toks, jnp.float32(0.0))
    loss_t = float(outs[-2])
    loss_e, _acc = jax.jit(fn_e)(*[p0e[s.name] for s in e_entries], toks)
    assert loss_t == pytest.approx(float(loss_e), rel=1e-5)
