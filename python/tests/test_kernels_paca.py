"""L1 correctness: PaCA gradient / gather / scatter Pallas kernels vs the
pure-jnp oracles, swept over shapes and index patterns with hypothesis.

∇P = (ᵖX_in)ᵀ∇X_out is the single new op PaCA adds to backprop (paper
Eq. 9); everything in the paper's speed/memory story rests on it being
exactly the restriction of the full weight gradient to the selected
rows — tested directly here and against autodiff in test_peft.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gather as gather_k
from compile.kernels import paca_grad as paca_k
from compile.kernels import ref as kref


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             dtype=jnp.float32)


def _idx(key, d_in, r):
    return jax.random.permutation(
        jax.random.PRNGKey(key), d_in)[:r].astype(jnp.int32)


@given(t=st.integers(1, 300), r=st.integers(1, 48),
       dout=st.integers(1, 200))
def test_paca_grad_matches_ref(t, r, dout):
    xp = _rand(0, t, r)
    dy = _rand(1, t, dout)
    got = paca_k.paca_grad(xp, dy)
    want = kref.paca_grad_ref(xp, dy)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(t=st.integers(1, 200), din=st.integers(2, 160),
       data=st.data())
def test_paca_grad_fused_matches_ref(t, din, data):
    r = data.draw(st.integers(1, din))
    dout = data.draw(st.integers(1, 96))
    x = _rand(2, t, din)
    dy = _rand(3, t, dout)
    idx = _idx(4, din, r)
    got = paca_k.paca_grad_fused(x, idx, dy)
    want = kref.paca_grad_fused_ref(x, idx, dy)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_paca_grad_is_row_restriction_of_full_grad():
    """∇P must equal the idx-rows of the full ∇W = X_inᵀ∇X_out."""
    t, din, dout, r = 64, 50, 40, 8
    x, dy = _rand(5, t, din), _rand(6, t, dout)
    idx = _idx(7, din, r)
    full_dw = x.T @ dy
    dp = paca_k.paca_grad(kref.gather_cols_ref(x, idx), dy)
    np.testing.assert_allclose(dp, full_dw[idx, :], rtol=1e-4, atol=1e-4)


def test_paca_grad_fused_equals_unfused():
    t, din, dout, r = 100, 70, 30, 16
    x, dy = _rand(8, t, din), _rand(9, t, dout)
    idx = _idx(10, din, r)
    a = paca_k.paca_grad(gather_k.gather_cols(x, idx), dy)
    b = paca_k.paca_grad_fused(x, idx, dy)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_paca_grad_duplicate_indices():
    """Fused gather tolerates repeated indices (each repeat contributes
    its own gradient row, matching the gather-then-matmul semantics)."""
    x, dy = _rand(11, 16, 10), _rand(12, 16, 6)
    idx = jnp.array([3, 3, 0, 9], jnp.int32)
    np.testing.assert_allclose(
        paca_k.paca_grad_fused(x, idx, dy),
        kref.paca_grad_fused_ref(x, idx, dy), rtol=1e-5, atol=1e-5)


def test_paca_grad_zero_dy_gives_zero():
    xp = _rand(13, 32, 8)
    dp = paca_k.paca_grad(xp, jnp.zeros((32, 24)))
    assert float(jnp.abs(dp).max()) == 0.0


@given(t=st.integers(1, 400), din=st.integers(1, 128), data=st.data())
def test_gather_cols(t, din, data):
    r = data.draw(st.integers(1, din))
    x = _rand(14, t, din)
    idx = _idx(15, din, r)
    np.testing.assert_array_equal(gather_k.gather_cols(x, idx),
                                  kref.gather_cols_ref(x, idx))


@given(din=st.integers(2, 100), dout=st.integers(1, 80), data=st.data())
def test_scatter_rows(din, dout, data):
    r = data.draw(st.integers(1, din))
    w = _rand(16, din, dout)
    p = _rand(17, r, dout)
    idx = _idx(18, din, r)
    got = gather_k.scatter_rows(w, idx, p)
    want = kref.scatter_rows_ref(w, idx, p)
    np.testing.assert_array_equal(got, want)
    # untouched rows must be bit-identical
    mask = jnp.ones(din, bool).at[idx].set(False)
    np.testing.assert_array_equal(got[mask], w[mask])


def test_scatter_then_gather_roundtrip():
    w = _rand(19, 64, 32)
    idx = _idx(20, 64, 12)
    p = _rand(21, 12, 32)
    w2 = gather_k.scatter_rows(w, idx, p)
    np.testing.assert_array_equal(jnp.take(w2, idx, axis=0), p)


def test_vmem_and_flops_estimates_positive():
    assert paca_k.vmem_bytes(512, 64, 4096) > 0
    assert paca_k.mxu_flops(512, 64, 4096) == 2 * 512 * 64 * 4096
