"""ViT substrate (paper Appendix B / Table 6): patchify, shapes, PEFT
integration, and short-horizon training for LoRA vs PaCA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, train_step, vit
from compile.configs import PeftConfig

CFG = configs.model("vit-tiny")


def test_patchify_shapes_and_inverse_energy():
    imgs = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 32, 32))
    p = vit.patchify(imgs)
    assert p.shape == (2, 64, 48)
    # patchify is a permutation of entries: energy preserved
    np.testing.assert_allclose(jnp.sum(p ** 2), jnp.sum(imgs ** 2),
                               rtol=1e-6)


def test_patchify_block_content():
    """Patch 0 must be exactly the top-left 4×4 of each channel."""
    imgs = jnp.arange(2 * 3 * 32 * 32, dtype=jnp.float32) \
        .reshape(2, 3, 32, 32)
    p = vit.patchify(imgs)
    want = imgs[0, :, :4, :4].transpose(1, 2, 0).reshape(-1)
    np.testing.assert_array_equal(p[0, 0], want)


@pytest.mark.parametrize("method", ["lora", "paca"])
def test_vit_forward_shape(method):
    pcfg = PeftConfig(method=method, rank=4)
    params, _reg = vit.init_vit(jax.random.PRNGKey(0), CFG, pcfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 32))
    logits = vit.forward(params, imgs, CFG, pcfg)
    assert logits.shape == (3, vit.N_CLASSES)


@pytest.mark.parametrize("method", ["lora", "paca"])
def test_vit_trains(method):
    pcfg = PeftConfig(method=method, rank=4)
    fn, entries, _b, p0, _reg = train_step.build_train_step(
        CFG, pcfg, batch=4, seq=65, kind="vit")
    state = train_step.initial_state(entries, p0)
    imgs = jax.random.normal(jax.random.PRNGKey(2), (4, 3, 32, 32))
    labels = jax.random.randint(jax.random.PRNGKey(3), (4,), 0,
                                vit.N_CLASSES)
    jfn = jax.jit(fn)
    upd = [e for e in entries if e.updated]
    n2i = {e.name: i for i, e in enumerate(entries)}
    losses = []
    for _ in range(6):
        outs = jfn(*state, imgs, labels, jnp.float32(3e-3))
        for j, e in enumerate(upd):
            state[n2i[e.name]] = outs[j]
        losses.append(float(outs[-2]))
    assert losses[-1] < losses[0]


def test_vit_paca_head_is_trainable_but_backbone_frozen():
    pcfg = PeftConfig(method="paca", rank=4)
    _params, reg = vit.init_vit(jax.random.PRNGKey(0), CFG, pcfg)
    roles = {s.name: s.role for s in reg.specs}
    assert roles["head/w"] == "trainable"
    assert roles["patch/w"] == "frozen"
    assert roles["blocks/0/q/w"] == "paca_w"
