//! `cargo bench --bench serve_throughput` — the serving pipeline
//! bench, online edition:
//!
//!   1. Anchor: on a fully-arrived queue the online scheduler must
//!      reproduce the offline planner's dispatch sequence (swap
//!      counts asserted equal for fifo and swap-aware), and offline
//!      plan replay under a capacity-bounded registry must show
//!      grouping reducing both swaps and cold loads (asserted).
//!   2. Online continuous batching on a bursty multi-tenant SLO trace,
//!      per policy, on the deterministic analytic clock: queueing-
//!      delay percentiles, deadline misses (slo-aware must beat
//!      fifo — asserted), swaps, virtual throughput.
//!   3. Iteration-level vs whole-batch head-to-head on a bursty
//!      DECODE-heavy trace (analytic clock): the iteration-level loop
//!      frees slots as requests finish and admits same-tenant joiners
//!      mid-generation, so it must cut p99 queueing delay vs the
//!      whole-batch unit of service (asserted; operating point
//!      validated over 40 seeds by simulation — worst-seed margin
//!      1.11x, the pinned seed's ~1.3x, and deadline misses improve
//!      on all 40 seeds too).
//!   4. KV-constrained decode under a paged block budget: a two-class
//!      SLO trace (background tenants with long no-deadline
//!      generations, interactive tenants with short deadlined
//!      requests) served slo-aware with preemption enabled vs
//!      drain-only under the SAME `--kv-blocks` budget. Peak/mean KV
//!      occupancy and preemption counters are emitted, and preemption
//!      must cut deadline misses (asserted; operating point validated
//!      over 40 seeds by simulation — worst-seed margin 22 misses,
//!      pinned seed 77→16).
//!   5. Prefix-sharing radix cache on a shared-prefix decode trace
//!      (per-tenant 48-token system prompts): cache on vs off under
//!      the same paged pool, slo-aware, analytic clock. The cache
//!      must produce a nonzero hit rate, cut BOTH total computed
//!      prefill tokens and TTFT p99, and not add deadline misses
//!      (asserted; hit/donation/reclaim counters emitted).
//!   6. Chunked prefill + speculative prefetch on a long-prompt
//!      heavy-tail trace (tenant 0 all 96-token prompts, tenant 1
//!      short interactive with 60ms deadlines): `--prefill-chunk-
//!      tokens 16` vs unchunked under the same clock and slot count.
//!      Chunking must cut decode TPOT p99 (decode slots keep flowing
//!      past long prompts) with UNCHANGED total computed tokens, no
//!      TTFT-p99 regression for the short-prompt tenant, and no
//!      added deadline misses (all asserted). Then prefetch on vs
//!      off over a sparse shared-prefix trace: idle gaps must donate
//!      blocks ahead of arrivals, cutting TTFT p99 without adding
//!      real (non-speculative) compute (asserted).
//!   7. Measured wall-clock host-GEMM throughput per policy under a
//!      capacity-bounded registry (cold tenants reload from disk).
//!   8. Multi-replica cluster under a flash crowd: 4 replicas on the
//!      merged virtual clock, the whole Zipf-skewed trace compressed
//!      into a 1/8-span arrival window, per router policy. FNV-1a
//!      sharding sends 47% of the load to one home replica (~2x its
//!      saturation rate) while the balanced quarter-share stays near
//!      capacity, so `least-loaded` and `warmth` (whose cold-path
//!      overflow spill kicks in the moment the home congests) must
//!      BOTH cut merged p99 queueing vs `shard` without adding
//!      deadline misses (asserted), with every request served exactly
//!      once and clean per-replica + merged-stream audits. Then a
//!      `--kill-replica`-style failover run: replica 1 dies at the
//!      median flash arrival with a full backlog, and the run must
//!      still complete every request exactly once with nonzero
//!      failover re-routes and clean audits (asserted).
//!
//! Emits BENCH_serve.json (per-policy queueing p50/p99, misses,
//! throughput, per-unit decode head-to-head, KV-pressure preemption
//! head-to-head, prefix-cache on/off head-to-head, chunked-prefill
//! and prefetch head-to-heads, per-router-policy flash-crowd cluster
//! head-to-head) to seed the perf trajectory. Runs on a fresh
//! checkout: host backend, synthetic base + adapters, no artifacts
//! required.

use std::collections::BTreeMap;
use std::path::Path;

use paca::manifest::ModelInfo;
use paca::metrics::LatencyRecorder;
use paca::serve::cluster::Cluster;
use paca::serve::engine::{BaseModel, ClockModel, HostBackend,
                          ServeEngine};
use paca::serve::events::Events;
use paca::serve::registry::{AdapterRegistry, PacaAdapter};
use paca::serve::router::RouterPolicy;
use paca::serve::scheduler::{plan, swap_count, OnlineScheduler,
                             Policy};
use paca::serve::trace::{self, ArrivalPattern, Trace, TraceSpec};
use paca::util::json::Json;

/// Serving geometry: big enough that an adapter swap (rank-64 row
/// splice + possible disk reload) is visible next to a small-batch
/// forward — the trade-off the scheduler exists to manage.
fn bench_model() -> ModelInfo {
    ModelInfo { name: "serve-bench".into(), vocab: 512, d_model: 128,
                n_layers: 2, n_heads: 4, d_ff: 344, max_seq: 128,
                profile_only: false }
}

const RANK: usize = 64;
const N_REQUESTS: usize = 256;
const N_TENANTS: usize = 8;
const MEAN_TOKENS: usize = 16;
const BATCH: usize = 8;

/// Deterministic virtual service model for the online sections: a
/// swap costs several batch quanta, so a swap-heavy dispatch order
/// visibly overloads the virtual server at this trace's arrival rate
/// while a coalescing order keeps it comfortably under capacity.
/// (`serve_online` feeds `swap_s` to the slo policy as its swap
/// penalty.)
const CLOCK: ClockModel = ClockModel::Analytic {
    swap_s: 5e-3, batch_s: 1e-3, token_s: 5e-5,
};

/// Bursty SLO trace: ~278 req/s offered in bursts, 60ms deadlines.
fn bursty_trace() -> Trace {
    trace::synthesize(&TraceSpec {
        n_requests: N_REQUESTS,
        n_tenants: N_TENANTS,
        mean_tokens: MEAN_TOKENS,
        burstiness: 4.0,
        deadline_ms: 60.0,
        ..Default::default()
    })
}

/// Decode-heavy bursty SLO trace for the iteration-level head-to-head:
/// each request owes a mean of 24 decode iterations after prefill, so
/// a whole-batch unit of service holds the server for its longest
/// member while iteration-level serving frees slots early and admits
/// same-tenant joiners mid-generation.
fn decode_trace() -> Trace {
    trace::synthesize(&TraceSpec {
        n_requests: N_REQUESTS,
        n_tenants: 4,
        mean_tokens: MEAN_TOKENS,
        decode_tokens: 24,
        burstiness: 4.0,
        deadline_ms: 60.0,
        req_per_s: 35.0,
        ..Default::default()
    })
}

/// Analytic clock for the decode head-to-head: every iteration pays a
/// 0.5ms step overhead + 50µs/token, swaps 5ms. Both units of service
/// pay identical per-step arithmetic (the whole-batch run charges
/// `(1 + max decode)·batch_s`), so the comparison isolates WHEN work
/// is scheduled, not how it is priced.
const DECODE_CLOCK: ClockModel = ClockModel::Analytic {
    swap_s: 5e-3, batch_s: 5e-4, token_s: 5e-5,
};

/// KV pool for the preemption head-to-head: 16 blocks × 16 tokens —
/// roughly two background sequences' lifetime caches, so concurrency
/// is genuinely memory-limited.
const KV_BLOCKS: usize = 16;
const KV_BLOCK_TOKENS: usize = 16;

/// Pool for the prefix-cache head-to-head: roomy enough that the
/// batch itself fits, tight enough that cached chains come under
/// pressure so the LRU reclaim actually fires (validated over 40
/// seeds by simulation: all five asserts hold on 40/40, reclaim
/// fires on 35/40, worst-seed TTFT-p99 margin ~14ms; pinned seed 42:
/// prefill tokens 16201→4860, TTFT p99 96→68ms, misses 29→7, 23
/// blocks reclaimed).
const PREFIX_KV_BLOCKS: usize = 20;

/// Shared-prefix decode trace: every tenant's requests open with the
/// SAME 48-token system prompt (three full 16-token blocks), then a
/// short unique tail and a small decode phase — the workload where a
/// prefix cache converts repeat prefill into block reuse.
fn shared_prefix_trace() -> Trace {
    trace::synthesize(&TraceSpec {
        n_requests: N_REQUESTS,
        n_tenants: 4,
        mean_tokens: MEAN_TOKENS,
        decode_tokens: 8,
        burstiness: 4.0,
        deadline_ms: 60.0,
        req_per_s: 35.0,
        shared_prefix_tokens: 48,
        ..Default::default()
    })
}

/// Two-class SLO workload for the preemption section, derived
/// deterministically from the decode trace: even tenants are
/// BACKGROUND (3× decode length, no deadline — batch generation that
/// loses nothing but recompute when evicted), odd tenants are
/// INTERACTIVE (quarter-length decodes, 60ms deadlines). The regime
/// where decode preemption pays: a long no-SLO batch holds the server
/// and its blocks while rescuable deadlines queue behind it.
fn two_class_trace() -> Trace {
    let mut tr = decode_trace();
    for r in &mut tr.requests {
        if r.tenant.index() % 2 == 0 {
            r.decode_tokens *= 3;
            r.deadline_s = f64::INFINITY;
        } else {
            r.decode_tokens = (r.decode_tokens / 4).max(1);
        }
    }
    tr
}

/// Replica count for the cluster section — matched by the shard-home
/// arithmetic below.
const N_REPLICAS: usize = 4;

/// Flash-crowd trace for the cluster section: the full Zipf-skewed
/// 8-tenant trace retimed into a window 1/8 of the nominal span, so
/// the in-window offered rate is 8 x 150 = 1200 req/s against an
/// aggregate 4-replica capacity of ~1100 req/s on the decode clock
/// (16 prefill + 16 decode tokens at ~3.5ms/request each). The
/// routing skew is deterministic: FNV-1a homes tenants {000, 004}
/// (Zipf shares 0.398 + 0.068 = 47% of the load) on replica 0, so
/// pure sharding drives one replica to ~2x its saturation rate while
/// the fair quarter share stays near capacity — the regime where
/// load-aware routing pays and load-blind affinity drowns.
fn flash_trace() -> Trace {
    trace::synthesize(&TraceSpec {
        n_requests: N_REQUESTS,
        n_tenants: N_TENANTS,
        mean_tokens: MEAN_TOKENS,
        decode_tokens: 16,
        deadline_ms: 60.0,
        req_per_s: 150.0,
        arrival_pattern: ArrivalPattern::Flash,
        ..Default::default()
    })
}

fn engine_for(tr: &Trace, adapters_dir: Option<&Path>) -> ServeEngine {
    let model = bench_model();
    let base = BaseModel::synthetic(&model, 7);
    let mut reg = match adapters_dir {
        // Capacity below the tenant count: an interleaved dispatch
        // order thrashes the cache, a coalescing one loads each
        // adapter ~once per residency.
        Some(dir) => AdapterRegistry::with_dir(dir,
                                               (N_TENANTS / 2).max(2)),
        None => AdapterRegistry::new(64),
    };
    if adapters_dir.is_none() {
        for name in tr.pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &model, RANK, 11));
        }
    }
    ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                     tr.pool.clone())
}

struct OnlineResult {
    swaps: u64,
    offline_swaps: usize,
    queue_p50_ms: f64,
    queue_p99_ms: f64,
    e2e_p99_ms: f64,
    misses: u64,
    deadline_total: u64,
    virt_req_per_s: f64,
    wall_req_per_s: f64,
    loads: u64,
}

fn run_online(policy: Policy, clock: ClockModel,
              adapters_dir: Option<&Path>) -> OnlineResult {
    let tr = bursty_trace();
    let offline_swaps = swap_count(
        &plan(tr.requests.clone(), BATCH, policy));
    let mut eng = engine_for(&tr, adapters_dir);
    let mut sched = OnlineScheduler::new(tr.requests, tr.pool.len(),
                                         BATCH, policy);
    eng.serve_online(&mut sched, clock).expect("serve");
    eng.finish().expect("bit-exact base restore");
    assert_eq!(eng.stats.requests as usize, N_REQUESTS,
               "every request must be served exactly once");
    let pq = |rec: &paca::metrics::LatencyRecorder, q: f64| {
        rec.percentile("(all)", q).unwrap_or(0.0) * 1e3
    };
    OnlineResult {
        swaps: eng.stats.swaps,
        offline_swaps,
        queue_p50_ms: pq(&eng.queueing, 0.50),
        queue_p99_ms: pq(&eng.queueing, 0.99),
        e2e_p99_ms: pq(&eng.e2e, 0.99),
        misses: eng.stats.deadline_misses,
        deadline_total: eng.stats.deadline_total,
        virt_req_per_s: eng.virtual_req_per_s(),
        wall_req_per_s: eng.throughput_req_per_s(),
        loads: eng.registry.stats.loads,
    }
}

/// Replay the offline plan through the engine against a
/// capacity-bounded disk registry; returns (swaps, cold loads).
fn run_offline_replay(policy: Policy,
                      adapters_dir: &Path) -> (u64, u64) {
    let tr = bursty_trace();
    let batches = plan(tr.requests.clone(), BATCH, policy);
    let mut eng = engine_for(&tr, Some(adapters_dir));
    eng.serve(&batches).expect("offline replay");
    eng.finish().expect("bit-exact base restore");
    (eng.stats.swaps, eng.registry.stats.loads)
}

fn main() {
    let model = bench_model();

    // Shared on-disk adapter store for the registry-bounded sections.
    let adapters_dir = std::env::temp_dir().join(format!(
        "paca-serve-bench-{}", std::process::id()));
    std::fs::create_dir_all(&adapters_dir).unwrap();
    for name in bursty_trace().pool.names() {
        PacaAdapter::synthetic(name, &model, RANK, 11)
            .save(&AdapterRegistry::adapter_path(&adapters_dir, name))
            .unwrap();
    }

    // ---- 1. Online/offline anchor: fully-arrived queues. ----------
    println!("== anchor: online scheduler vs offline plan \
              (fully-arrived queue) ==");
    for policy in [Policy::Fifo, Policy::SwapAware] {
        let tr = bursty_trace();
        let offline = plan(tr.requests.clone(), BATCH, policy);
        let mut sched = OnlineScheduler::new(
            tr.requests, tr.pool.len(), BATCH, policy);
        let online = sched.drain_fully_arrived();
        assert_eq!(online.len(), offline.len(),
                   "{policy:?}: batch counts diverge");
        assert_eq!(swap_count(&online), swap_count(&offline),
                   "{policy:?}: swap counts diverge");
        for (a, b) in online.iter().zip(&offline) {
            assert_eq!(a.tenant, b.tenant, "{policy:?}");
            assert_eq!(a.requests.len(), b.requests.len(),
                       "{policy:?}");
        }
        println!("  {:>10}: {} batches, {} swaps — online == offline",
                 policy.name(), offline.len(), swap_count(&offline));
    }

    // ---- 1b. Offline plan replay under a thrashing registry: the
    // planner's deterministic invariant — grouping can only reduce
    // swaps and cold loads (swap-aware touches each tenant once, so
    // it loads each adapter exactly once).
    println!("\n== offline plan replay (registry capacity {} of \
              {N_TENANTS} tenants) ==", (N_TENANTS / 2).max(2));
    let (fifo_sw, fifo_ld) = run_offline_replay(Policy::Fifo,
                                                &adapters_dir);
    let (aware_sw, aware_ld) = run_offline_replay(Policy::SwapAware,
                                                  &adapters_dir);
    println!("  fifo: {fifo_sw} swaps, {fifo_ld} loads | swap-aware: \
              {aware_sw} swaps, {aware_ld} loads");
    assert!(aware_sw <= fifo_sw,
            "offline swap-aware must not add swaps");
    assert!(aware_ld <= fifo_ld,
            "offline swap-aware must not add registry loads: \
             {aware_ld} !<= {fifo_ld}");
    let mut results: Vec<Json> = Vec::new();
    for (policy, sw, ld) in [("fifo", fifo_sw, fifo_ld),
                             ("swap-aware", aware_sw, aware_ld)] {
        let mut obj = BTreeMap::new();
        obj.insert("policy".into(), Json::Str(policy.into()));
        obj.insert("clock".into(), Json::Str("offline-replay".into()));
        obj.insert("swaps".into(), Json::Num(sw as f64));
        obj.insert("loads".into(), Json::Num(ld as f64));
        results.push(Json::Obj(obj));
    }

    // ---- 2. Online continuous batching, analytic clock. -----------
    println!("\n== online pipeline: bursty trace ({N_REQUESTS} reqs, \
              {N_TENANTS} tenants, 60ms deadlines, analytic clock) ==");
    println!("{:>11} {:>6} {:>9} {:>10} {:>10} {:>10} {:>9} {:>11}",
             "policy", "swaps", "off.swaps", "q p50 ms", "q p99 ms",
             "e2e p99", "misses", "virt req/s");
    let mut by_policy: BTreeMap<&str, OnlineResult> = BTreeMap::new();
    for policy in Policy::ALL {
        let r = run_online(policy, CLOCK, None);
        println!("{:>11} {:>6} {:>9} {:>10.3} {:>10.3} {:>10.3} \
                  {:>6}/{:<3} {:>11.1}",
                 policy.name(), r.swaps, r.offline_swaps,
                 r.queue_p50_ms, r.queue_p99_ms, r.e2e_p99_ms,
                 r.misses, r.deadline_total, r.virt_req_per_s);
        let mut obj = BTreeMap::new();
        obj.insert("policy".into(), Json::Str(policy.name().into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("swaps".into(), Json::Num(r.swaps as f64));
        obj.insert("offline_swaps".into(),
                   Json::Num(r.offline_swaps as f64));
        obj.insert("queue_p50_ms".into(), Json::Num(r.queue_p50_ms));
        obj.insert("queue_p99_ms".into(), Json::Num(r.queue_p99_ms));
        obj.insert("e2e_p99_ms".into(), Json::Num(r.e2e_p99_ms));
        obj.insert("deadline_misses".into(),
                   Json::Num(r.misses as f64));
        obj.insert("deadline_total".into(),
                   Json::Num(r.deadline_total as f64));
        obj.insert("virt_req_per_s".into(),
                   Json::Num(r.virt_req_per_s));
        results.push(Json::Obj(obj));
        by_policy.insert(policy.name(), r);
    }

    // Deterministic invariants of the analytic-clock runs.
    let fifo = &by_policy["fifo"];
    let aware = &by_policy["swap-aware"];
    let slo = &by_policy["slo-aware"];
    assert!(aware.swaps <= fifo.swaps,
            "swap-aware must not add swaps over fifo");
    assert!(slo.misses < fifo.misses,
            "slo-aware must reduce deadline misses vs fifo on the \
             bursty trace: {} !< {}", slo.misses, fifo.misses);
    assert!(slo.queue_p99_ms < fifo.queue_p99_ms,
            "slo-aware must cut tail queueing vs fifo: {} !< {}",
            slo.queue_p99_ms, fifo.queue_p99_ms);
    assert_eq!(fifo.deadline_total as usize, N_REQUESTS);
    println!("\nslo-aware vs fifo: misses {} -> {} ({:.0}% fewer), \
              queue p99 {:.1}ms -> {:.1}ms",
             fifo.misses, slo.misses,
             100.0 * (fifo.misses - slo.misses) as f64
                 / (fifo.misses as f64).max(1.0),
             fifo.queue_p99_ms, slo.queue_p99_ms);

    // ---- 3. Iteration-level vs whole-batch on a decode trace. -----
    println!("\n== decode head-to-head: iteration-level vs \
              whole-batch (bursty trace, mean 24 decode tokens, \
              analytic clock, swap-aware) ==");
    struct UnitResult {
        queue_p50_ms: f64,
        queue_p99_ms: f64,
        ttft_p99_ms: f64,
        misses: u64,
        swaps: u64,
        steps: u64,
        mean_slots: f64,
    }
    let run_unit = |iterative: bool| -> UnitResult {
        let tr = decode_trace();
        let mut eng = engine_for(&tr, None);
        let mut sched = OnlineScheduler::new(
            tr.requests, tr.pool.len(), BATCH, Policy::SwapAware);
        if iterative {
            eng.serve_iterative(&mut sched, DECODE_CLOCK)
                .expect("serve_iterative");
        } else {
            eng.serve_online(&mut sched, DECODE_CLOCK)
                .expect("serve_online");
        }
        eng.finish().expect("bit-exact base restore");
        assert_eq!(eng.stats.requests as usize, N_REQUESTS);
        let pq = |rec: &paca::metrics::LatencyRecorder, q: f64| {
            rec.percentile("(all)", q).unwrap_or(0.0) * 1e3
        };
        UnitResult {
            queue_p50_ms: pq(&eng.queueing, 0.50),
            queue_p99_ms: pq(&eng.queueing, 0.99),
            ttft_p99_ms: pq(&eng.ttft, 0.99),
            misses: eng.stats.deadline_misses,
            swaps: eng.stats.swaps,
            steps: eng.stats.steps,
            mean_slots: eng.occupancy.mean_slots(),
        }
    };
    let whole = run_unit(false);
    let iter = run_unit(true);
    println!("{:>16} {:>10} {:>10} {:>10} {:>8} {:>7} {:>7} {:>6}",
             "unit", "q p50 ms", "q p99 ms", "ttft p99", "misses",
             "swaps", "steps", "occ");
    println!("{:>16} {:>10.3} {:>10.3} {:>10} {:>8} {:>7} {:>7} {:>6}",
             "whole-batch", whole.queue_p50_ms, whole.queue_p99_ms,
             "-", whole.misses, whole.swaps, "-", "-");
    println!("{:>16} {:>10.3} {:>10.3} {:>10.3} {:>8} {:>7} {:>7} \
              {:>6.1}",
             "iteration-level", iter.queue_p50_ms, iter.queue_p99_ms,
             iter.ttft_p99_ms, iter.misses, iter.swaps, iter.steps,
             iter.mean_slots);
    // The tentpole's payoff, asserted on the deterministic clock:
    // splitting the unit of service into token steps cuts tail
    // queueing (slots free early + mid-generation joins) without
    // giving back deadline misses.
    assert!(iter.queue_p99_ms < whole.queue_p99_ms,
            "iteration-level must cut p99 queueing on a decode-heavy \
             bursty trace: {} !< {}",
            iter.queue_p99_ms, whole.queue_p99_ms);
    assert!(iter.misses <= whole.misses,
            "iteration-level must not add deadline misses: {} > {}",
            iter.misses, whole.misses);
    assert!(iter.steps as usize > N_REQUESTS / BATCH,
            "decode work must actually be served step-wise");
    println!("\niteration-level vs whole-batch: queue p99 {:.1}ms -> \
              {:.1}ms ({:.0}% lower), misses {} -> {}",
             whole.queue_p99_ms, iter.queue_p99_ms,
             100.0 * (1.0 - iter.queue_p99_ms / whole.queue_p99_ms),
             whole.misses, iter.misses);
    for (unit, r) in [("whole-batch", &whole),
                      ("iteration-level", &iter)] {
        let mut obj = BTreeMap::new();
        obj.insert("unit".into(), Json::Str(unit.into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("trace".into(), Json::Str("decode-bursty".into()));
        obj.insert("queue_p50_ms".into(), Json::Num(r.queue_p50_ms));
        obj.insert("queue_p99_ms".into(), Json::Num(r.queue_p99_ms));
        obj.insert("deadline_misses".into(),
                   Json::Num(r.misses as f64));
        obj.insert("swaps".into(), Json::Num(r.swaps as f64));
        // TTFT/steps/occupancy only exist for the iteration-level
        // unit — omit the keys (like the console's "-") rather than
        // writing fabricated zeros into the perf trajectory.
        if unit == "iteration-level" {
            obj.insert("ttft_p99_ms".into(),
                       Json::Num(r.ttft_p99_ms));
            obj.insert("steps".into(), Json::Num(r.steps as f64));
            obj.insert("mean_slots".into(),
                       Json::Num(r.mean_slots));
        }
        results.push(Json::Obj(obj));
    }

    // ---- 4. KV-constrained decode: preemption vs drain-only. ------
    println!("\n== kv-constrained decode: preemption vs drain-only \
              ({KV_BLOCKS} x {KV_BLOCK_TOKENS}-token blocks, \
              two-class SLO trace, slo-aware, analytic clock) ==");
    struct KvResult {
        misses: u64,
        deadline_total: u64,
        preemptions: u64,
        preempt_memory: u64,
        preempt_deadline: u64,
        peak_blocks: usize,
        mean_blocks: f64,
        peak_kv_tokens: usize,
        recompute_tokens: u64,
        overflow_tokens: u64,
        queue_p99_ms: f64,
    }
    let run_kv = |preempt: bool| -> KvResult {
        let tr = two_class_trace();
        let mut eng = engine_for(&tr, None);
        eng.configure_kv(KV_BLOCKS, KV_BLOCK_TOKENS, preempt);
        let mut sched = OnlineScheduler::new(
            tr.requests, tr.pool.len(), BATCH, Policy::SloAware);
        eng.serve_iterative(&mut sched, DECODE_CLOCK)
            .expect("serve_iterative under kv budget");
        eng.finish().expect("clean drain: no leaked blocks, no \
                             stranded preemptions");
        assert_eq!(eng.stats.requests as usize, N_REQUESTS,
                   "every request served exactly once");
        KvResult {
            misses: eng.stats.deadline_misses,
            deadline_total: eng.stats.deadline_total,
            preemptions: eng.stats.preemptions,
            preempt_memory: eng.stats.preempt_memory,
            preempt_deadline: eng.stats.preempt_deadline,
            peak_blocks: eng.kv.stats.peak_blocks,
            mean_blocks: eng.kv_timeline.mean_blocks(),
            peak_kv_tokens: eng.kv.stats.peak_tokens,
            recompute_tokens: eng.stats.kv_recompute_tokens,
            overflow_tokens: eng.kv.stats.overflow_tokens,
            queue_p99_ms: eng.queueing.percentile("(all)", 0.99)
                .unwrap_or(0.0) * 1e3,
        }
    };
    let drain = run_kv(false);
    let pre = run_kv(true);
    println!("{:>12} {:>10} {:>9} {:>8} {:>9} {:>8} {:>10}",
             "mode", "misses", "preempts", "mem/dl", "peak kv",
             "mean kv", "recompute");
    for (mode, r) in [("drain-only", &drain), ("preempt", &pre)] {
        println!("{:>12} {:>6}/{:<3} {:>9} {:>8} {:>5}/{:<3} \
                  {:>8.1} {:>10}",
                 mode, r.misses, r.deadline_total, r.preemptions,
                 format!("{}/{}", r.preempt_memory,
                         r.preempt_deadline),
                 r.peak_blocks, KV_BLOCKS, r.mean_blocks,
                 r.recompute_tokens);
    }
    // The tentpole's capacity-axis payoff, on the deterministic
    // clock: under one block budget, evicting deadline-free decodes
    // for rescuable deadlines must cut misses — and the ledger must
    // prove no over-commit in either mode.
    assert!(drain.preemptions == 0,
            "drain-only must never preempt");
    assert!(pre.preemptions >= 1,
            "the budget must actually force preemption");
    assert!(pre.misses < drain.misses,
            "preemption must cut deadline misses vs drain-only: \
             {} !< {}", pre.misses, drain.misses);
    assert!(drain.peak_blocks <= KV_BLOCKS
            && pre.peak_blocks <= KV_BLOCKS,
            "block over-commit: {}/{} vs budget {KV_BLOCKS}",
            drain.peak_blocks, pre.peak_blocks);
    println!("\npreemption vs drain-only: misses {} -> {} ({:.0}% \
              fewer), queue p99 {:.1}ms -> {:.1}ms, {} preemptions \
              ({} memory, {} deadline), {} recompute tokens",
             drain.misses, pre.misses,
             100.0 * (drain.misses - pre.misses) as f64
                 / (drain.misses as f64).max(1.0),
             drain.queue_p99_ms, pre.queue_p99_ms, pre.preemptions,
             pre.preempt_memory, pre.preempt_deadline,
             pre.recompute_tokens);
    for (mode, r) in [("drain-only", &drain), ("preempt", &pre)] {
        let mut obj = BTreeMap::new();
        obj.insert("mode".into(), Json::Str(mode.into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("trace".into(),
                   Json::Str("two-class-decode".into()));
        obj.insert("kv_blocks".into(), Json::Num(KV_BLOCKS as f64));
        obj.insert("kv_block_tokens".into(),
                   Json::Num(KV_BLOCK_TOKENS as f64));
        obj.insert("peak_kv_blocks".into(),
                   Json::Num(r.peak_blocks as f64));
        obj.insert("mean_kv_blocks".into(), Json::Num(r.mean_blocks));
        obj.insert("peak_kv_tokens".into(),
                   Json::Num(r.peak_kv_tokens as f64));
        obj.insert("deadline_misses".into(),
                   Json::Num(r.misses as f64));
        obj.insert("deadline_total".into(),
                   Json::Num(r.deadline_total as f64));
        obj.insert("preemptions".into(),
                   Json::Num(r.preemptions as f64));
        obj.insert("preempt_memory".into(),
                   Json::Num(r.preempt_memory as f64));
        obj.insert("preempt_deadline".into(),
                   Json::Num(r.preempt_deadline as f64));
        obj.insert("recompute_tokens".into(),
                   Json::Num(r.recompute_tokens as f64));
        obj.insert("overflow_tokens".into(),
                   Json::Num(r.overflow_tokens as f64));
        obj.insert("queue_p99_ms".into(), Json::Num(r.queue_p99_ms));
        results.push(Json::Obj(obj));
    }

    // ---- 5. Prefix-sharing cache: on vs off, shared-prefix trace. -
    println!("\n== prefix cache: shared 48-token system prompts \
              ({N_REQUESTS} reqs, 4 tenants, mean 8 decode tokens, \
              {PREFIX_KV_BLOCKS} x {KV_BLOCK_TOKENS}-token blocks, \
              slo-aware, analytic clock) ==");
    struct PrefixResult {
        tokens: u64,
        prefill_tokens: u64,
        ttft_p99_ms: f64,
        misses: u64,
        hits: u64,
        hit_tokens: u64,
        hit_rate: f64,
        donated: u64,
        reclaimed: u64,
        cow_forks: u64,
        preemptions: u64,
    }
    let run_prefix = |cache: bool| -> PrefixResult {
        let tr = shared_prefix_trace();
        let mut eng = engine_for(&tr, None);
        eng.configure_kv(PREFIX_KV_BLOCKS, KV_BLOCK_TOKENS, true);
        eng.configure_prefix(cache);
        let mut sched = OnlineScheduler::new(
            tr.requests, tr.pool.len(), BATCH, Policy::SloAware);
        eng.serve_iterative(&mut sched, DECODE_CLOCK)
            .expect("serve_iterative over shared prefixes");
        let ttft_p99_ms = eng.ttft.percentile("(all)", 0.99)
            .unwrap_or(0.0) * 1e3;
        eng.finish().expect("clean drain: no leaked blocks or \
                             refcounts");
        assert_eq!(eng.stats.requests as usize, N_REQUESTS,
                   "every request served exactly once");
        let ps = eng.prefix.stats;
        PrefixResult {
            tokens: eng.stats.tokens,
            prefill_tokens: eng.stats.prefill_tokens
                - ps.hit_tokens,
            ttft_p99_ms,
            misses: eng.stats.deadline_misses,
            hits: ps.hits,
            hit_tokens: ps.hit_tokens,
            hit_rate: ps.hit_tokens as f64
                / eng.stats.prefill_tokens.max(1) as f64,
            donated: ps.donated_blocks,
            reclaimed: ps.reclaimed_blocks,
            cow_forks: eng.kv.stats.cow_forks,
            preemptions: eng.stats.preemptions,
        }
    };
    let cold = run_prefix(false);
    let warm = run_prefix(true);
    println!("{:>8} {:>10} {:>12} {:>10} {:>8} {:>9} {:>9} {:>9}",
             "cache", "tokens", "prefill tok", "ttft p99", "misses",
             "hits", "donated", "reclaimed");
    for (mode, r) in [("off", &cold), ("on", &warm)] {
        println!("{:>8} {:>10} {:>12} {:>10.3} {:>6}/{:<3} {:>9} \
                  {:>9} {:>9}",
                 mode, r.tokens, r.prefill_tokens, r.ttft_p99_ms,
                 r.misses, N_REQUESTS, r.hits, r.donated,
                 r.reclaimed);
    }
    // The tentpole's payoff, on the deterministic clock: shared
    // prefixes stop being recomputed — a real hit rate, strictly
    // fewer computed prefill tokens, a TTFT p99 win — without
    // giving back deadline misses.
    assert!(warm.hits > 0 && warm.hit_tokens > 0,
            "the shared-prefix trace must actually hit the cache");
    assert_eq!(cold.hits, 0, "off-mode must never touch the cache");
    assert!(warm.prefill_tokens < cold.prefill_tokens,
            "cache-on must cut computed prefill tokens: {} !< {}",
            warm.prefill_tokens, cold.prefill_tokens);
    assert!(warm.tokens < cold.tokens,
            "…and total computed tokens: {} !< {}", warm.tokens,
            cold.tokens);
    assert!(warm.ttft_p99_ms < cold.ttft_p99_ms,
            "cache-on must cut TTFT p99: {} !< {}", warm.ttft_p99_ms,
            cold.ttft_p99_ms);
    assert!(warm.misses <= cold.misses,
            "cache-on must not add deadline misses: {} > {}",
            warm.misses, cold.misses);
    println!("\nprefix cache on vs off: prefill tokens {} -> {} \
              ({:.0}% hit rate), ttft p99 {:.1}ms -> {:.1}ms, misses \
              {} -> {}, {} cow forks, {} reclaimed blocks",
             cold.prefill_tokens, warm.prefill_tokens,
             100.0 * warm.hit_rate, cold.ttft_p99_ms,
             warm.ttft_p99_ms, cold.misses, warm.misses,
             warm.cow_forks, warm.reclaimed);
    for (mode, r) in [("off", &cold), ("on", &warm)] {
        let mut obj = BTreeMap::new();
        obj.insert("prefix_cache".into(), Json::Str(mode.into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("trace".into(),
                   Json::Str("shared-prefix-decode".into()));
        obj.insert("kv_blocks".into(),
                   Json::Num(PREFIX_KV_BLOCKS as f64));
        obj.insert("tokens".into(), Json::Num(r.tokens as f64));
        obj.insert("prefill_tokens".into(),
                   Json::Num(r.prefill_tokens as f64));
        obj.insert("ttft_p99_ms".into(), Json::Num(r.ttft_p99_ms));
        obj.insert("deadline_misses".into(),
                   Json::Num(r.misses as f64));
        obj.insert("hits".into(), Json::Num(r.hits as f64));
        obj.insert("hit_tokens".into(),
                   Json::Num(r.hit_tokens as f64));
        obj.insert("hit_rate".into(), Json::Num(r.hit_rate));
        obj.insert("donated_blocks".into(),
                   Json::Num(r.donated as f64));
        obj.insert("reclaimed_blocks".into(),
                   Json::Num(r.reclaimed as f64));
        obj.insert("cow_forks".into(), Json::Num(r.cow_forks as f64));
        obj.insert("preemptions".into(),
                   Json::Num(r.preemptions as f64));
        results.push(Json::Obj(obj));
    }

    // ---- 6. Chunked prefill + speculative prefetch. ---------------
    println!("\n== chunked prefill: long-prompt heavy-tail trace \
              (tenant 0 all 96-token prompts, tenant 1 short \
              interactive w/ 60ms deadlines, chunk 16, analytic \
              clock, slo-aware) ==");
    // Tenant 0 is the long-prompt class: every request a 96-token
    // prompt (the heavy tail), deadline-free, short decode. Tenant 1
    // keeps the bursty short-prompt interactive profile. Unchunked,
    // each 96-token prefill is one atomic step that stalls every
    // co-resident decode slot and blocks urgent switches for its
    // whole duration; chunked, the same work lands 16 tokens at a
    // time between decode steps.
    let long_prompt_trace = || {
        let mut tr = trace::synthesize(&TraceSpec {
            n_requests: N_REQUESTS,
            n_tenants: 2,
            mean_tokens: MEAN_TOKENS,
            decode_tokens: 24,
            burstiness: 4.0,
            deadline_ms: 60.0,
            req_per_s: 35.0,
            ..Default::default()
        });
        for r in &mut tr.requests {
            if r.tenant.index() == 0 {
                r.tokens = 96;
                r.decode_tokens = 4;
                r.deadline_s = f64::INFINITY;
            }
        }
        tr
    };
    struct ChunkResult {
        tokens: u64,
        tpot_p99_ms: f64,
        ttft_short_p99_ms: f64,
        misses: u64,
        prefill_chunks: u64,
        chunked_prefills: u64,
        steps: u64,
    }
    let run_chunk = |chunk: usize| -> ChunkResult {
        let tr = long_prompt_trace();
        let mut eng = engine_for(&tr, None);
        eng.configure_chunking(chunk);
        let mut sched = OnlineScheduler::new(
            tr.requests, tr.pool.len(), BATCH, Policy::SloAware);
        sched.prefill_chunk_tokens = chunk;
        eng.serve_iterative(&mut sched, DECODE_CLOCK)
            .expect("serve_iterative chunked");
        let pq = |rec: &paca::metrics::LatencyRecorder, key: &str| {
            rec.percentile(key, 0.99).unwrap_or(0.0) * 1e3
        };
        let r = ChunkResult {
            tokens: eng.stats.tokens,
            tpot_p99_ms: pq(&eng.tpot, "(all)"),
            ttft_short_p99_ms: pq(&eng.ttft,
                                  &trace::tenant_name(1)),
            misses: eng.stats.deadline_misses,
            prefill_chunks: eng.stats.prefill_chunks,
            chunked_prefills: eng.stats.chunked_prefills,
            steps: eng.stats.steps,
        };
        eng.finish().expect("clean drain after chunked serve");
        r
    };
    let whole_pf = run_chunk(0);
    let chunked = run_chunk(16);
    println!("{:>10} {:>10} {:>11} {:>13} {:>8} {:>8} {:>8}",
             "chunking", "tokens", "tpot p99 ms", "short ttft p99",
             "misses", "chunks", "steps");
    for (mode, r) in [("off", &whole_pf), ("chunk-16", &chunked)] {
        println!("{:>10} {:>10} {:>11.3} {:>13.3} {:>8} {:>8} {:>8}",
                 mode, r.tokens, r.tpot_p99_ms, r.ttft_short_p99_ms,
                 r.misses, r.prefill_chunks, r.steps);
    }
    // The tentpole's payoff on the deterministic clock: same total
    // work, split so decode slots never stall behind a long prompt —
    // and the finer step granularity must not cost the interactive
    // tenant its TTFT tail or any deadline.
    assert_eq!(chunked.tokens, whole_pf.tokens,
               "chunking must not change total computed tokens");
    assert!(chunked.chunked_prefills > 0,
            "the 96-token prompts must actually split");
    assert!(chunked.tpot_p99_ms < whole_pf.tpot_p99_ms,
            "chunked prefill must cut decode TPOT p99: {} !< {}",
            chunked.tpot_p99_ms, whole_pf.tpot_p99_ms);
    assert!(chunked.ttft_short_p99_ms <= whole_pf.ttft_short_p99_ms,
            "short-prompt TTFT p99 must not regress: {} !<= {}",
            chunked.ttft_short_p99_ms, whole_pf.ttft_short_p99_ms);
    assert!(chunked.misses <= whole_pf.misses,
            "chunking must not add deadline misses: {} > {}",
            chunked.misses, whole_pf.misses);
    println!("\nchunked vs unchunked: decode tpot p99 {:.2}ms -> \
              {:.2}ms ({:.0}% lower), short-tenant ttft p99 {:.1}ms \
              -> {:.1}ms, misses {} -> {}, {} prompts split over {} \
              chunk steps",
             whole_pf.tpot_p99_ms, chunked.tpot_p99_ms,
             100.0 * (1.0 - chunked.tpot_p99_ms
                      / whole_pf.tpot_p99_ms.max(1e-12)),
             whole_pf.ttft_short_p99_ms, chunked.ttft_short_p99_ms,
             whole_pf.misses, chunked.misses,
             chunked.chunked_prefills, chunked.prefill_chunks);
    for (mode, r) in [("off", &whole_pf), ("chunk-16", &chunked)] {
        let mut obj = BTreeMap::new();
        obj.insert("chunking".into(), Json::Str(mode.into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("trace".into(),
                   Json::Str("long-prompt-heavy-tail".into()));
        obj.insert("tokens".into(), Json::Num(r.tokens as f64));
        obj.insert("tpot_p99_ms".into(), Json::Num(r.tpot_p99_ms));
        obj.insert("ttft_short_p99_ms".into(),
                   Json::Num(r.ttft_short_p99_ms));
        obj.insert("deadline_misses".into(),
                   Json::Num(r.misses as f64));
        obj.insert("prefill_chunks".into(),
                   Json::Num(r.prefill_chunks as f64));
        obj.insert("chunked_prefills".into(),
                   Json::Num(r.chunked_prefills as f64));
        obj.insert("steps".into(), Json::Num(r.steps as f64));
        results.push(Json::Obj(obj));
    }

    // ---- 6b. Speculative prefix prefetch on a sparse trace. -------
    println!("\n== speculative prefetch: sparse shared-prefix trace \
              (4 req/s, 48-token system prompts, prefix cache on, \
              analytic clock, slo-aware) ==");
    let sparse_prefix_trace = || {
        trace::synthesize(&TraceSpec {
            n_requests: 64,
            n_tenants: 4,
            mean_tokens: MEAN_TOKENS,
            decode_tokens: 8,
            deadline_ms: 60.0,
            req_per_s: 4.0,
            shared_prefix_tokens: 48,
            ..Default::default()
        })
    };
    struct PrefetchResult {
        tokens: u64,
        prefetch_tokens: u64,
        donated: u64,
        hit_tokens: u64,
        ttft_p99_ms: f64,
    }
    let run_prefetch = |prefetch: bool| -> PrefetchResult {
        let tr = sparse_prefix_trace();
        let mut eng = engine_for(&tr, None);
        eng.configure_prefix(true);
        eng.configure_prefetch(prefetch);
        let mut sched = OnlineScheduler::new(
            tr.requests, tr.pool.len(), BATCH, Policy::SloAware);
        eng.serve_iterative(&mut sched, DECODE_CLOCK)
            .expect("serve_iterative with prefetch");
        let r = PrefetchResult {
            tokens: eng.stats.tokens,
            prefetch_tokens: eng.stats.prefetch_tokens,
            donated: eng.stats.prefetch_donated_blocks,
            hit_tokens: eng.prefix.stats.hit_tokens,
            ttft_p99_ms: eng.ttft.percentile("(all)", 0.99)
                .unwrap_or(0.0) * 1e3,
        };
        eng.finish().expect("clean drain after prefetch serve");
        r
    };
    let no_warm = run_prefetch(false);
    let warmed = run_prefetch(true);
    println!("{:>10} {:>10} {:>13} {:>9} {:>10} {:>10}",
             "prefetch", "tokens", "spec tokens", "donated",
             "hit tok", "ttft p99");
    for (mode, r) in [("off", &no_warm), ("on", &warmed)] {
        println!("{:>10} {:>10} {:>13} {:>9} {:>10} {:>10.3}",
                 mode, r.tokens, r.prefetch_tokens, r.donated,
                 r.hit_tokens, r.ttft_p99_ms);
    }
    // Idle gaps dwarf a 48-token warm on this clock, so the cold
    // per-tenant first requests — the off-run's TTFT tail — find
    // their prefix already resident.
    assert_eq!(no_warm.prefetch_tokens, 0,
               "prefetch off must do no speculative work");
    assert!(warmed.donated > 0,
            "idle gaps before arrivals must donate blocks");
    assert!(warmed.hit_tokens >= no_warm.hit_tokens,
            "a pre-warmed cache cannot hit less: {} !>= {}",
            warmed.hit_tokens, no_warm.hit_tokens);
    assert!(warmed.ttft_p99_ms < no_warm.ttft_p99_ms,
            "prefetch must cut TTFT p99 on the sparse trace: \
             {} !< {}", warmed.ttft_p99_ms, no_warm.ttft_p99_ms);
    assert!(warmed.tokens - warmed.prefetch_tokens <= no_warm.tokens,
            "speculative work must replace demand prefill, not add \
             real compute: {} - {} vs {}", warmed.tokens,
            warmed.prefetch_tokens, no_warm.tokens);
    println!("\nprefetch on vs off: ttft p99 {:.2}ms -> {:.2}ms, {} \
              blocks donated ahead of arrival, hit tokens {} -> {}",
             no_warm.ttft_p99_ms, warmed.ttft_p99_ms, warmed.donated,
             no_warm.hit_tokens, warmed.hit_tokens);
    for (mode, r) in [("off", &no_warm), ("on", &warmed)] {
        let mut obj = BTreeMap::new();
        obj.insert("prefetch".into(), Json::Str(mode.into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("trace".into(),
                   Json::Str("sparse-shared-prefix".into()));
        obj.insert("tokens".into(), Json::Num(r.tokens as f64));
        obj.insert("prefetch_tokens".into(),
                   Json::Num(r.prefetch_tokens as f64));
        obj.insert("donated_blocks".into(),
                   Json::Num(r.donated as f64));
        obj.insert("hit_tokens".into(),
                   Json::Num(r.hit_tokens as f64));
        obj.insert("ttft_p99_ms".into(), Json::Num(r.ttft_p99_ms));
        results.push(Json::Obj(obj));
    }

    // ---- 7. Measured wall-clock host serving, thrashing registry. -
    println!("\n== measured host-GEMM wall clock (registry capacity \
              {} of {N_TENANTS} tenants) ==", (N_TENANTS / 2).max(2));
    println!("{:>11} {:>9} {:>7} {:>7}", "policy", "req/s", "swaps",
             "loads");
    let mut measured: BTreeMap<&str, OnlineResult> = BTreeMap::new();
    for policy in Policy::ALL {
        let r = run_online(policy, ClockModel::Measured,
                           Some(adapters_dir.as_path()));
        println!("{:>11} {:>9.1} {:>7} {:>7}", policy.name(),
                 r.wall_req_per_s, r.swaps, r.loads);
        let mut obj = BTreeMap::new();
        obj.insert("policy".into(), Json::Str(policy.name().into()));
        obj.insert("clock".into(), Json::Str("measured".into()));
        obj.insert("req_per_s".into(), Json::Num(r.wall_req_per_s));
        obj.insert("swaps".into(), Json::Num(r.swaps as f64));
        obj.insert("loads".into(), Json::Num(r.loads as f64));
        results.push(Json::Obj(obj));
        measured.insert(policy.name(), r);
    }
    // Wall-clock comparisons are noise-prone on shared CI runners, so
    // this is a hard failure only under PACA_BENCH_STRICT=1; the
    // analytic-clock asserts above are the deterministic invariant.
    let (f, a) = (&measured["fifo"], &measured["swap-aware"]);
    if a.wall_req_per_s <= f.wall_req_per_s {
        let msg = format!(
            "swap-aware did not beat FIFO on measured wall clock: \
             {:.1} vs {:.1} req/s", a.wall_req_per_s,
            f.wall_req_per_s);
        if std::env::var("PACA_BENCH_STRICT").is_ok() {
            panic!("{msg}");
        }
        println!("WARNING: {msg} (timing noise on this host?)");
    }

    // ---- 8. Cluster flash crowd: router policies head-to-head. ----
    println!("\n== cluster flash crowd: {N_REPLICAS} replicas, \
              {N_REQUESTS} reqs in a 1/8-span window (Zipf tenants, \
              60ms deadlines, analytic clock, slo-aware) ==");
    struct ClusterResult {
        queue_p50_ms: f64,
        queue_p99_ms: f64,
        ttft_p99_ms: f64,
        misses: u64,
        requests: u64,
        alive: Vec<bool>,
        home: u64,
        warm: u64,
        steal: u64,
        spill: u64,
        failover: u64,
    }
    // Prefix cache OFF on every replica: `warm_tokens` advertises a
    // tenant's resident chain wherever its LAST request landed, which
    // under a flash makes warmth's warm-path sticky to arrival
    // history. With the cache off all three policies see identical
    // cold signals, so the head-to-head isolates the ROUTING rule —
    // and warmth exercises exactly its documented cold path: shard
    // affinity until the home congests, then overflow spill.
    let run_cluster = |rpolicy: RouterPolicy,
                       kill: Option<(usize, f64)>| -> ClusterResult {
        let tr = flash_trace();
        let parts = (0..N_REPLICAS).map(|_| {
            let mut eng = engine_for(&tr, None);
            eng.configure_events(Events::recording());
            let sched = OnlineScheduler::new(
                Vec::new(), tr.pool.len(), BATCH, Policy::SloAware);
            (eng, sched)
        }).collect();
        let mut cl = Cluster::new(parts, tr.requests.clone(), rpolicy,
                                  BATCH, kill);
        cl.run(DECODE_CLOCK).expect("cluster serve");
        let audit = cl.audit();
        assert_eq!(audit.violation_count(), 0,
                   "{}: merged-stream audit must be clean: {:?}",
                   rpolicy.name(), audit.violations());
        let mut queueing = LatencyRecorder::default();
        let mut ttft = LatencyRecorder::default();
        let (mut misses, mut requests) = (0u64, 0u64);
        for rep in &cl.replicas {
            assert_eq!(rep.engine.events.violation_count(), 0,
                       "{}: per-replica audit must be clean",
                       rpolicy.name());
            queueing.absorb(&rep.engine.queueing);
            ttft.absorb(&rep.engine.ttft);
            misses += rep.engine.stats.deadline_misses;
            requests += rep.engine.stats.requests;
        }
        let pq = |rec: &LatencyRecorder, q: f64| {
            rec.percentile("(all)", q).unwrap_or(0.0) * 1e3
        };
        let rs = cl.router.stats;
        ClusterResult {
            queue_p50_ms: pq(&queueing, 0.50),
            queue_p99_ms: pq(&queueing, 0.99),
            ttft_p99_ms: pq(&ttft, 0.99),
            misses,
            requests,
            alive: cl.replicas.iter().map(|r| r.alive).collect(),
            home: rs.home,
            warm: rs.warm,
            steal: rs.steal,
            spill: rs.spill,
            failover: rs.failover,
        }
    };
    println!("{:>13} {:>10} {:>10} {:>10} {:>8} {:>6} {:>6} {:>6} \
              {:>6}",
             "router", "q p50 ms", "q p99 ms", "ttft p99", "misses",
             "home", "steal", "spill", "fail");
    let mut by_router: BTreeMap<&str, ClusterResult> = BTreeMap::new();
    for rpolicy in RouterPolicy::ALL {
        let r = run_cluster(rpolicy, None);
        assert_eq!(r.requests as usize, N_REQUESTS,
                   "{}: every request served exactly once",
                   rpolicy.name());
        println!("{:>13} {:>10.3} {:>10.3} {:>10.3} {:>5}/{:<3} \
                  {:>6} {:>6} {:>6} {:>6}",
                 rpolicy.name(), r.queue_p50_ms, r.queue_p99_ms,
                 r.ttft_p99_ms, r.misses, N_REQUESTS, r.home,
                 r.steal, r.spill, r.failover);
        let mut obj = BTreeMap::new();
        obj.insert("router".into(),
                   Json::Str(rpolicy.name().into()));
        obj.insert("clock".into(), Json::Str("analytic".into()));
        obj.insert("trace".into(), Json::Str("flash-crowd".into()));
        obj.insert("replicas".into(), Json::Num(N_REPLICAS as f64));
        obj.insert("queue_p50_ms".into(), Json::Num(r.queue_p50_ms));
        obj.insert("queue_p99_ms".into(), Json::Num(r.queue_p99_ms));
        obj.insert("ttft_p99_ms".into(), Json::Num(r.ttft_p99_ms));
        obj.insert("deadline_misses".into(),
                   Json::Num(r.misses as f64));
        obj.insert("home_routes".into(), Json::Num(r.home as f64));
        obj.insert("warm_routes".into(), Json::Num(r.warm as f64));
        obj.insert("steals".into(), Json::Num(r.steal as f64));
        obj.insert("spills".into(), Json::Num(r.spill as f64));
        obj.insert("failover".into(), Json::Num(r.failover as f64));
        results.push(Json::Obj(obj));
        by_router.insert(rpolicy.name(), r);
    }
    // The tentpole's payoff, on the deterministic merged clock:
    // load-blind sharding drowns its 47%-share home replica in the
    // flash while the other three idle down; both load-aware
    // policies must cut merged tail queueing without giving back a
    // single deadline — and the router counters must show HOW (pure
    // sharding never leaves home, least-loaded steals, warmth spills
    // its congested home).
    let shard = &by_router["shard"];
    let ll = &by_router["least-loaded"];
    let warmr = &by_router["warmth"];
    assert_eq!((shard.steal, shard.spill, shard.failover), (0, 0, 0),
               "shard must route every request home");
    assert!(ll.steal > 0, "the flash must force least-loaded away \
                           from home shards");
    assert!(warmr.spill > 0, "the flash must congest warmth's home \
                              shard past the spill threshold");
    assert!(ll.queue_p99_ms < shard.queue_p99_ms,
            "least-loaded must cut merged p99 queueing vs shard \
             under the flash crowd: {} !< {}",
            ll.queue_p99_ms, shard.queue_p99_ms);
    assert!(warmr.queue_p99_ms < shard.queue_p99_ms,
            "warmth's overflow spill must cut merged p99 queueing vs \
             shard under the flash crowd: {} !< {}",
            warmr.queue_p99_ms, shard.queue_p99_ms);
    assert!(ll.misses <= shard.misses,
            "least-loaded must not add deadline misses: {} > {}",
            ll.misses, shard.misses);
    assert!(warmr.misses <= shard.misses,
            "warmth must not add deadline misses: {} > {}",
            warmr.misses, shard.misses);
    println!("\nleast-loaded vs shard: queue p99 {:.1}ms -> {:.1}ms \
              ({:.0}% lower), misses {} -> {}; warmth (spill x{}) \
              p99 {:.1}ms, misses {}",
             shard.queue_p99_ms, ll.queue_p99_ms,
             100.0 * (1.0 - ll.queue_p99_ms
                      / shard.queue_p99_ms.max(1e-12)),
             shard.misses, ll.misses, warmr.spill,
             warmr.queue_p99_ms, warmr.misses);

    // ---- 8b. Failover: kill a replica at the median flash arrival.
    let kill_t = {
        let tr = flash_trace();
        let mut at: Vec<f64> = tr.requests.iter()
            .map(|r| r.arrival_s).collect();
        at.sort_by(|a, b| a.partial_cmp(b).unwrap());
        at[at.len() / 2]
    };
    let killed = run_cluster(RouterPolicy::LeastLoaded,
                             Some((1, kill_t)));
    assert_eq!(killed.requests as usize, N_REQUESTS,
               "failover must not lose or duplicate a request");
    assert!(!killed.alive[1], "the kill must have fired");
    assert!(killed.failover > 0,
            "a replica killed mid-flash must hold work to migrate");
    println!("\nkill replica 1 @ {:.3}s (median flash arrival, \
              least-loaded): {} requests re-routed, {}/{} served \
              exactly once, audits clean, misses {}",
             kill_t, killed.failover, killed.requests, N_REQUESTS,
             killed.misses);
    let mut obj = BTreeMap::new();
    obj.insert("router".into(), Json::Str("least-loaded".into()));
    obj.insert("clock".into(), Json::Str("analytic".into()));
    obj.insert("trace".into(), Json::Str("flash-crowd".into()));
    obj.insert("replicas".into(), Json::Num(N_REPLICAS as f64));
    obj.insert("kill_replica".into(), Json::Num(1.0));
    obj.insert("kill_t_s".into(), Json::Num(kill_t));
    obj.insert("failover".into(), Json::Num(killed.failover as f64));
    obj.insert("queue_p99_ms".into(),
               Json::Num(killed.queue_p99_ms));
    obj.insert("deadline_misses".into(),
               Json::Num(killed.misses as f64));
    results.push(Json::Obj(obj));

    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("serve_throughput".into()));
    root.insert("model".into(), Json::Str(model.name.clone()));
    root.insert("rank".into(), Json::Num(RANK as f64));
    root.insert("requests".into(), Json::Num(N_REQUESTS as f64));
    root.insert("batch".into(), Json::Num(BATCH as f64));
    root.insert("results".into(), Json::Arr(results));
    std::fs::write("BENCH_serve.json", Json::Obj(root).to_string())
        .unwrap();
    println!("\nwrote BENCH_serve.json");
    std::fs::remove_dir_all(&adapters_dir).ok();
}
