//! `cargo bench --bench serve_throughput` — multi-tenant serving:
//! requests/sec vs adapter count and batch size, FIFO vs swap-aware
//! batching, under a capacity-bounded registry (cold tenants reload
//! from disk — the regime where batching policy matters). Emits
//! BENCH_serve.json to seed the perf trajectory.
//!
//! Runs on a fresh checkout: host GEMM backend, synthetic base +
//! adapters, no artifacts required.

use std::collections::BTreeMap;
use std::path::PathBuf;

use paca::manifest::ModelInfo;
use paca::serve::engine::{Backend, BaseModel, ServeEngine};
use paca::serve::registry::{AdapterRegistry, PacaAdapter};
use paca::serve::scheduler::{plan, swap_count, Policy};
use paca::serve::trace::{self, TraceSpec};
use paca::util::json::Json;

/// Serving geometry: big enough that an adapter swap (rank-64 row
/// splice + possible disk reload) is visible next to a small-batch
/// forward — the trade-off the scheduler exists to manage.
fn bench_model() -> ModelInfo {
    ModelInfo { name: "serve-bench".into(), vocab: 512, d_model: 128,
                n_layers: 2, n_heads: 4, d_ff: 344, max_seq: 128,
                profile_only: false }
}

const RANK: usize = 64;
const N_REQUESTS: usize = 192;
const MEAN_TOKENS: usize = 16;

struct RunResult {
    req_per_s: f64,
    tok_per_s: f64,
    swaps: u64,
    loads: u64,
    batches: usize,
    p95_ms: f64,
}

fn run_once(model: &ModelInfo, adapters_dir: &PathBuf,
            n_tenants: usize, batch: usize, policy: Policy)
            -> RunResult {
    let spec = TraceSpec { n_requests: N_REQUESTS, n_tenants,
                           mean_tokens: MEAN_TOKENS,
                           ..Default::default() };
    let requests = trace::synthesize(&spec);
    let batches = plan(&requests, batch, policy);
    // Capacity below the tenant count: the interleaved order thrashes
    // the cache, the grouped order loads each adapter once.
    let reg = AdapterRegistry::with_dir(adapters_dir,
                                        (n_tenants / 2).max(2));
    let base = BaseModel::synthetic(model, 7);
    let mut eng = ServeEngine::new(base, reg, Backend::Host);
    eng.serve(&batches).expect("serve");
    eng.finish().expect("bit-exact base restore");
    RunResult {
        req_per_s: eng.throughput_req_per_s(),
        tok_per_s: eng.throughput_tok_per_s(),
        swaps: eng.stats.swaps,
        loads: eng.registry.stats.loads,
        batches: batches.len(),
        p95_ms: eng.latencies.percentile("(all)", 0.95)
            .unwrap_or(0.0) * 1e3,
    }
}

fn main() {
    let model = bench_model();
    let adapters_dir = std::env::temp_dir().join(format!(
        "paca-serve-bench-{}", std::process::id()));
    std::fs::create_dir_all(&adapters_dir).unwrap();
    let max_tenants = 16;
    for i in 0..max_tenants {
        let t = trace::tenant_name(i);
        PacaAdapter::synthetic(&t, &model, RANK, 11)
            .save(&AdapterRegistry::adapter_path(&adapters_dir, &t))
            .unwrap();
    }

    println!("== serve throughput: {} requests, rank {RANK}, d={} ==",
             N_REQUESTS, model.d_model);
    println!("{:>8} {:>6} {:>11} {:>9} {:>7} {:>7} {:>9} {:>9}",
             "tenants", "batch", "policy", "req/s", "swaps", "loads",
             "batches", "p95 ms");

    let mut results: Vec<Json> = Vec::new();
    for &n_tenants in &[4usize, 16] {
        for &batch in &[1usize, 4, 16] {
            let mut per_policy = BTreeMap::new();
            for policy in [Policy::Fifo, Policy::SwapAware] {
                let r = run_once(&model, &adapters_dir, n_tenants,
                                 batch, policy);
                println!("{:>8} {:>6} {:>11} {:>9.1} {:>7} {:>7} \
                          {:>9} {:>9.3}",
                         n_tenants, batch, policy.name(), r.req_per_s,
                         r.swaps, r.loads, r.batches, r.p95_ms);
                let mut obj = BTreeMap::new();
                obj.insert("tenants".into(),
                           Json::Num(n_tenants as f64));
                obj.insert("batch".into(), Json::Num(batch as f64));
                obj.insert("policy".into(),
                           Json::Str(policy.name().into()));
                obj.insert("req_per_s".into(), Json::Num(r.req_per_s));
                obj.insert("tok_per_s".into(), Json::Num(r.tok_per_s));
                obj.insert("swaps".into(), Json::Num(r.swaps as f64));
                obj.insert("loads".into(), Json::Num(r.loads as f64));
                obj.insert("p95_ms".into(), Json::Num(r.p95_ms));
                results.push(Json::Obj(obj));
                per_policy.insert(policy.name(), r);
            }
            let fifo = &per_policy["fifo"];
            let aware = &per_policy["swap-aware"];
            // Deterministic invariant: grouping can only reduce swaps
            // and cold loads.
            assert!(aware.swaps <= fifo.swaps,
                    "swap-aware must not add swaps");
            assert!(aware.loads <= fifo.loads,
                    "swap-aware must not add registry loads");
            println!("{:>8} {:>6} {:>11} {:>+8.1}%  \
                      (swaps {} -> {}, loads {} -> {})",
                     "", "", "speedup",
                     (aware.req_per_s / fifo.req_per_s - 1.0) * 100.0,
                     fifo.swaps, aware.swaps, fifo.loads, aware.loads);
        }
    }

    // The headline comparison: interleaved tenants, per-request
    // batches, thrashing registry — swap-aware should win on wall
    // clock. Wall-clock comparisons are noise-prone on shared CI
    // runners, so this is a hard failure only under
    // PACA_BENCH_STRICT=1 (the swap/load-count asserts above are the
    // deterministic invariant).
    let fifo = run_once(&model, &adapters_dir, 16, 1, Policy::Fifo);
    let aware = run_once(&model, &adapters_dir, 16, 1,
                         Policy::SwapAware);
    println!("\nheadline (16 tenants, batch 1): fifo {:.1} req/s vs \
              swap-aware {:.1} req/s ({:+.1}%)",
             fifo.req_per_s, aware.req_per_s,
             (aware.req_per_s / fifo.req_per_s - 1.0) * 100.0);
    if aware.req_per_s <= fifo.req_per_s {
        let msg = format!(
            "swap-aware batching did not beat FIFO on the mixed-tenant \
             trace: {} vs {} req/s", aware.req_per_s, fifo.req_per_s);
        if std::env::var("PACA_BENCH_STRICT").is_ok() {
            panic!("{msg}");
        }
        println!("WARNING: {msg} (timing noise on this host?)");
    }

    // Sanity: plans are equivalent workloads.
    let spec = TraceSpec { n_requests: N_REQUESTS, n_tenants: 16,
                           mean_tokens: MEAN_TOKENS,
                           ..Default::default() };
    let reqs = trace::synthesize(&spec);
    assert!(swap_count(&plan(&reqs, 1, Policy::SwapAware))
            <= swap_count(&plan(&reqs, 1, Policy::Fifo)));

    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("serve_throughput".into()));
    root.insert("model".into(), Json::Str(model.name.clone()));
    root.insert("rank".into(), Json::Num(RANK as f64));
    root.insert("requests".into(), Json::Num(N_REQUESTS as f64));
    root.insert("results".into(), Json::Arr(results));
    std::fs::write("BENCH_serve.json", Json::Obj(root).to_string())
        .unwrap();
    println!("\nwrote BENCH_serve.json");
    std::fs::remove_dir_all(&adapters_dir).ok();
}
