//! `cargo bench --bench step_time` — end-to-end per-iteration latency
//! of every train-step artifact on the PJRT CPU client (the measured
//! half of Fig 2 / Tables 1–3 timing columns), plus dispatch-path
//! micro-benchmarks (H2D literal creation, batch generation).

use std::time::Duration;

use paca::config::TrainConfig;
use paca::coordinator::Trainer;
use paca::data::{Task, TokenGen};
use paca::runtime::Runtime;
use paca::tensor::HostTensor;
use paca::util::bench::bench;

fn main() {
    let rt = Runtime::new(&paca::default_artifacts_dir())
        .expect("run `make artifacts` first");
    println!("== train-step latency per method (tiny-lm, b=4, s=64) ==");
    let budget = Duration::from_secs(8);
    let mut results = Vec::new();
    for artifact in ["train_full_tiny", "train_lora_tiny",
                     "train_dora_tiny", "train_moslora_tiny",
                     "train_paca_tiny", "train_paca_tiny_r16",
                     "train_qlora_tiny", "train_qpaca_tiny"] {
        let mut cfg = TrainConfig::default();
        cfg.artifact = artifact.into();
        let mut tr = Trainer::new(&rt, cfg).expect(artifact);
        let r = bench(artifact, 3, 200, budget, || {
            tr.train_step().unwrap();
        });
        r.report();
        results.push((artifact, r.mean_ms()));
    }
    let lora = results.iter().find(|(a, _)| *a == "train_lora_tiny")
        .map(|(_, m)| *m).unwrap();
    let paca = results.iter().find(|(a, _)| *a == "train_paca_tiny")
        .map(|(_, m)| *m).unwrap();
    println!("\nPaCA vs LoRA step time: {:+.1}% (paper Fig 2: -19% \
              at LLaMA3-8B scale)\n",
             (paca / lora - 1.0) * 100.0);

    println!("== small-lm (b=8, s=128) ==");
    for artifact in ["train_paca_small", "train_lora_small"] {
        let mut cfg = TrainConfig::default();
        cfg.artifact = artifact.into();
        let mut tr = Trainer::new(&rt, cfg).expect(artifact);
        bench(artifact, 2, 60, budget, || {
            tr.train_step().unwrap();
        }).report();
    }

    println!("\n== dispatch-path micro-benchmarks ==");
    let mut gen = TokenGen::new(Task::Instr, 512, 1);
    bench("data: train_batch 4x64 (instr)", 10, 5000,
          Duration::from_secs(3), || {
              std::hint::black_box(gen.train_batch(4, 64));
          }).report();
    let batch = gen.train_batch(4, 64);
    bench("h2d: tokens literal 4x65 i32", 10, 5000,
          Duration::from_secs(3), || {
              std::hint::black_box(batch.to_literal().unwrap());
          }).report();
    let w = HostTensor::from_f32(&[512, 64], vec![0.5; 512 * 64]);
    bench("h2d: weight literal 512x64 f32", 10, 5000,
          Duration::from_secs(3), || {
              std::hint::black_box(w.to_literal().unwrap());
          }).report();

    println!("\n== eval-step latency ==");
    let mut cfg = TrainConfig::default();
    cfg.artifact = "train_paca_tiny".into();
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    bench("eval (4 categories x 1 batch)", 1, 50, budget, || {
        tr.evaluate(1).unwrap();
    }).report();
}
