//! `cargo bench --bench coordinator` — L3 substrate micro-benchmarks:
//! the cost-model sweeps behind Tables 1–4 / Fig 3, state
//! initialization, NF4 quantization, checkpoint I/O, manifest parsing,
//! and the data pipeline. These are the pure-rust hot paths the §Perf
//! pass optimizes.

use std::time::Duration;

use paca::data::{ImageGen, Task, TokenGen};
use paca::init;
use paca::manifest::Manifest;
use paca::memory;
use paca::nf4;
use paca::peft::Selection;
use paca::simulator::{self, A100_80G, GAUDI2};
use paca::util::bench::bench;
use paca::util::json::Json;
use paca::util::rng::Rng;

fn main() {
    let dir = paca::default_artifacts_dir();
    let budget = Duration::from_secs(3);

    println!("== analytic models (paper-scale sweeps) ==");
    let manifest = Manifest::load(&dir).expect("make artifacts");
    let m8b = manifest.model("llama3-8b").unwrap();
    bench("memory::breakdown x5 methods", 10, 100_000, budget, || {
        for method in ["full", "lora", "dora", "paca", "qpaca"] {
            std::hint::black_box(
                memory::breakdown(m8b, method, 8, 8, 512, true));
        }
    }).report();
    bench("memory::max_seq_len (table4 row)", 10, 100_000, budget,
          || {
              std::hint::black_box(memory::max_seq_len(
                  m8b, "paca", 8, 80e9, false));
          }).report();
    bench("simulator::iteration_time x2 devices", 10, 100_000, budget,
          || {
              for dev in [&A100_80G, &GAUDI2] {
                  std::hint::black_box(simulator::iteration_time(
                      dev, m8b, "lora", 8, 8, 512));
              }
          }).report();
    bench("fig3 full sweep (5 methods x batches)", 3, 2_000, budget,
          || {
              for method in ["full", "lora", "dora", "moslora", "paca"] {
                  let mb = memory::max_batch(m8b, method, 8, 512, 80e9,
                                             false);
                  for b in [2, 4, 8, 16] {
                      if b <= mb {
                          std::hint::black_box(
                              simulator::throughput_seq_per_s(
                                  &A100_80G, m8b, method, 8, b, 512));
                      }
                  }
              }
          }).report();

    println!("\n== init + quantization ==");
    let art = manifest.artifact("train_paca_tiny").unwrap().clone();
    bench("init_state(train_paca_tiny)", 3, 2_000, budget, || {
        std::hint::black_box(
            init::init_state(&art, 42, &Selection::Random).unwrap());
    }).report();
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..64 * 4096).map(|_| rng.normal_f32(0.02))
        .collect();
    bench("nf4::quantize 256K weights", 3, 2_000, budget, || {
        std::hint::black_box(nf4::quantize(&w, 64));
    }).report();
    let (codes, scales) = nf4::quantize(&w, 64);
    bench("nf4::dequantize 256K weights", 3, 2_000, budget, || {
        std::hint::black_box(nf4::dequantize(&codes, &scales, 64));
    }).report();

    println!("\n== manifest + checkpoint I/O ==");
    let src = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    bench("json parse manifest", 3, 2_000, budget, || {
        std::hint::black_box(Json::parse(&src).unwrap());
    }).report();
    let tensors = init::init_state(&art, 42, &Selection::Random).unwrap();
    let names: Vec<String> = art.state.iter().map(|e| e.name.clone())
        .collect();
    let path = std::env::temp_dir().join("paca-bench.ckpt");
    bench("checkpoint save (tiny state)", 2, 500, budget, || {
        paca::coordinator::checkpoint::save(&path, &names, &tensors)
            .unwrap();
    }).report();
    bench("checkpoint load (tiny state)", 2, 500, budget, || {
        std::hint::black_box(
            paca::coordinator::checkpoint::load(&path).unwrap());
    }).report();
    std::fs::remove_file(&path).ok();

    println!("\n== data pipeline ==");
    for task in [Task::LmZipf, Task::MmluLike, Task::Instr] {
        let mut gen = TokenGen::new(task, 2048, 1);
        bench(&format!("{:?} batch 8x128", task), 5, 20_000, budget,
              || {
                  std::hint::black_box(gen.train_batch(8, 128));
              }).report();
    }
    let mut ig = ImageGen::new(10, 1);
    bench("ImageGen batch 8x3x32x32", 5, 5_000, budget, || {
        std::hint::black_box(ig.batch(8));
    }).report();
}
