#!/usr/bin/env bash
# Full repro: the 40-seed robustness sweep behind the operating points
# the bench and smokes pin, then the kick-tires flow (bench +
# BENCH_serve.json + BENCH_summary.md).
#
# Per seed 1..40, through the real CLI on the analytic-deterministic
# paths:
#   * a heavy-tailed multi-turn chat trace served with 16-token
#     prefill chunks under a step budget, auditor recording — the run
#     exits nonzero on any invariant violation, and the chunk ledger
#     must appear in the report;
#   * the same trace unchunked (reduction anchor: must serve clean
#     with no chunk ledger line);
#   * a sparse shared-prefix trace with speculative prefetch +
#     cache-aware dispatch — donations must be nonzero every seed.
#
# Takes a few minutes. Artifacts are meant to be committed.

set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

BIN=target/release/paca
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

for seed in $(seq 1 40); do
    tail_trace="$WORK/tail_$seed.jsonl"
    warm_trace="$WORK/warm_$seed.jsonl"

    "$BIN" serve --backend host --batch 8 --count 48 --tenants 4 \
        --mean-tokens 8 --decode-tokens 8 --seed "$seed" \
        --prompt-tail 0.4 --chat-turns 3 \
        --policy slo-aware --deadline-ms 50 --req-per-s 1e9 \
        --prefill-chunk-tokens 16 --max-batch-tokens 96 \
        --trace-events "$WORK/events_$seed.jsonl" \
        --adapters "$WORK/adapters" \
        --requests "$tail_trace" > "$WORK/chunk_$seed.out"
    grep -q "auditor: clean" "$WORK/chunk_$seed.out"
    grep -q "prefill chunks:" "$WORK/chunk_$seed.out"
    grep -q "restored bit-exactly" "$WORK/chunk_$seed.out"

    "$BIN" serve --backend host --batch 8 --count 48 --tenants 4 \
        --mean-tokens 8 --decode-tokens 8 --seed "$seed" \
        --req-per-s 1e9 --adapters "$WORK/adapters" \
        --requests "$tail_trace" > "$WORK/unchunk_$seed.out"
    if grep -q "prefill chunks" "$WORK/unchunk_$seed.out"; then
        echo "seed $seed: unchunked run grew a chunk ledger" >&2
        exit 1
    fi
    grep -q "restored bit-exactly" "$WORK/unchunk_$seed.out"

    "$BIN" serve --backend host --batch 8 --count 24 --tenants 4 \
        --mean-tokens 8 --decode-tokens 8 --seed "$seed" \
        --shared-prefix-tokens 48 --req-per-s 5 \
        --prefetch on --cache-aware on --adapters "$WORK/adapters" \
        --requests "$warm_trace" > "$WORK/warm_$seed.out"
    grep -Eq "speculative prefetch: [1-9][0-9]* tokens" \
        "$WORK/warm_$seed.out"
    if grep -q " 0 blocks donated" "$WORK/warm_$seed.out"; then
        echo "seed $seed: prefetch donated nothing" >&2
        exit 1
    fi
    grep -q "restored bit-exactly" "$WORK/warm_$seed.out"

    echo "seed $seed: chunked clean, anchor clean, prefetch donated"
done

echo "40-seed sweep OK"
scripts/kick_tires.sh --skip-build
