#!/usr/bin/env bash
# Kick-the-tires repro: CI-sized. Builds, runs tier-1 tests, runs the
# serving bench on the deterministic analytic clock (every section's
# head-to-head asserts internally), and regenerates BENCH_serve.json
# plus a human-readable BENCH_summary.md from it. Run from anywhere;
# artifacts land in the repo root and are meant to be committed.
#
#   scripts/kick_tires.sh [--skip-build]
#
# --skip-build: reuse the existing release build + skip tier-1 tests
# (CI calls it this way right after its own build/test steps).

set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--skip-build" ]]; then
    cargo build --release
    cargo test -q
fi

cargo bench --bench serve_throughput

# Telemetry smoke: the live stack on a 2-replica cluster — streaming
# event sink, merged Prometheus-text registry, merged folded step
# profile. Mirrors the CI telemetry smoke: census, no NaN, monotone
# counters across scrape blocks, folded stacks parse, and the
# profiler partition invariant (Σ phase virtual time = step service
# time) from the report JSON.
cargo run --release --quiet -- serve --batch 8 --count 64 --tenants 4 \
    --replicas 2 --router least-loaded --mean-tokens 16 \
    --decode-tokens 16 --req-per-s 1e9 \
    --policy slo-aware --deadline-ms 50 \
    --trace-events serve_telemetry_events.jsonl \
    --metrics serve_metrics.prom --metrics-interval 0.0005 \
    --profile serve_profile.folded \
    --report-json serve_telemetry_report.json \
    --requests serve_trace_telemetry.jsonl

python3 - <<'EOF'
import json

text = open('serve_metrics.prom').read()
assert 'NaN' not in text, 'NaN sample in metrics output'
blocks, cur = [], None
for line in text.splitlines():
    if line.startswith('# scrape '):
        cur = {}
        blocks.append(cur)
        continue
    if not line or line.startswith('#'):
        continue
    series, value = line.rsplit(' ', 1)
    cur[series] = float(value)
assert blocks, 'no scrape blocks'
names = {s.split('{')[0] for b in blocks for s in b}
need = {'paca_events_total', 'paca_requests_completed_total',
        'paca_tokens_decoded_total', 'paca_slo_completions_total'}
assert need <= names, need - names
last = {}
for b in blocks:
    for series, value in b.items():
        if '_total' in series or '_count' in series or '_bucket' in series:
            assert value >= last.get(series, 0.0), (series, value)
            last[series] = value
folded = [l for l in open('serve_profile.folded').read().splitlines() if l]
for l in folded:
    stack, v = l.rsplit(' ', 1)
    assert int(v) >= 0 and ';' in stack, l
phases = {'admission', 'dispatch', 'prefill', 'decode',
          'kv_grow', 'prefix', 'router'}
got = {l.split(' ')[0].split(';')[-1]
       for l in folded if l.startswith('paca_serve;')}
assert phases <= got, phases - got
p = json.load(open('serve_telemetry_report.json'))['metrics']['profiler']
total = sum(ph['virtual_s'] for ph in p['phases'].values())
want = p['step_virtual_s']
assert abs(total - want) <= 1e-9 * max(want, 1.0), (total, want)
print(f"telemetry smoke ok: {len(blocks)} scrapes, "
      f"{len(folded)} folded lines, {int(p['steps'])} profiled steps")
EOF

python3 - <<'EOF'
import json

d = json.load(open("BENCH_serve.json"))
rows = d["results"]

# Each results row carries exactly one discriminator key; group by it.
SECTIONS = [
    ("policy",       "Online scheduling (per policy)"),
    ("unit",         "Unit of service: iteration-level vs whole-batch"),
    ("mode",         "KV pressure: preemption vs drain-only"),
    ("prefix_cache", "Prefix cache: on vs off"),
    ("chunking",     "Chunked prefill: long-prompt heavy tail"),
    ("prefetch",     "Speculative prefix prefetch: sparse arrivals"),
    ("router",       "Multi-replica cluster: router policies under flash crowd"),
]

def fmt(v):
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.3f}"
    if isinstance(v, float):
        return str(int(v))
    return str(v)

out = ["# Serving bench summary", ""]
out.append(f"Source: `BENCH_serve.json` (bench `{d['bench']}`, "
           f"model `{d['model']}`, rank {int(d['rank'])}, "
           f"{int(d['requests'])} requests, batch {int(d['batch'])}). "
           "All analytic-clock numbers are deterministic; the bench "
           "asserts every head-to-head before writing them.")
out.append("")
for key, title in SECTIONS:
    sect = [r for r in rows if key in r]
    if not sect:
        continue
    cols = [key] + sorted({c for r in sect for c in r} - {key})
    out.append(f"## {title}")
    out.append("")
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "---|" * len(cols))
    for r in sect:
        out.append("| " + " | ".join(
            fmt(r[c]) if c in r else "—" for c in cols) + " |")
    out.append("")

open("BENCH_summary.md", "w").write("\n".join(out))
print("wrote BENCH_summary.md "
      f"({len(rows)} result rows, {len(SECTIONS)} sections)")
EOF

echo "kick-tires OK: BENCH_serve.json + BENCH_summary.md regenerated"
