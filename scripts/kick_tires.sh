#!/usr/bin/env bash
# Kick-the-tires repro: CI-sized. Builds, runs tier-1 tests, runs the
# serving bench on the deterministic analytic clock (every section's
# head-to-head asserts internally), and regenerates BENCH_serve.json
# plus a human-readable BENCH_summary.md from it. Run from anywhere;
# artifacts land in the repo root and are meant to be committed.
#
#   scripts/kick_tires.sh [--skip-build]
#
# --skip-build: reuse the existing release build + skip tier-1 tests
# (CI calls it this way right after its own build/test steps).

set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--skip-build" ]]; then
    cargo build --release
    cargo test -q
fi

cargo bench --bench serve_throughput

python3 - <<'EOF'
import json

d = json.load(open("BENCH_serve.json"))
rows = d["results"]

# Each results row carries exactly one discriminator key; group by it.
SECTIONS = [
    ("policy",       "Online scheduling (per policy)"),
    ("unit",         "Unit of service: iteration-level vs whole-batch"),
    ("mode",         "KV pressure: preemption vs drain-only"),
    ("prefix_cache", "Prefix cache: on vs off"),
    ("chunking",     "Chunked prefill: long-prompt heavy tail"),
    ("prefetch",     "Speculative prefix prefetch: sparse arrivals"),
    ("router",       "Multi-replica cluster: router policies under flash crowd"),
]

def fmt(v):
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.3f}"
    if isinstance(v, float):
        return str(int(v))
    return str(v)

out = ["# Serving bench summary", ""]
out.append(f"Source: `BENCH_serve.json` (bench `{d['bench']}`, "
           f"model `{d['model']}`, rank {int(d['rank'])}, "
           f"{int(d['requests'])} requests, batch {int(d['batch'])}). "
           "All analytic-clock numbers are deterministic; the bench "
           "asserts every head-to-head before writing them.")
out.append("")
for key, title in SECTIONS:
    sect = [r for r in rows if key in r]
    if not sect:
        continue
    cols = [key] + sorted({c for r in sect for c in r} - {key})
    out.append(f"## {title}")
    out.append("")
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "---|" * len(cols))
    for r in sect:
        out.append("| " + " | ".join(
            fmt(r[c]) if c in r else "—" for c in cols) + " |")
    out.append("")

open("BENCH_summary.md", "w").write("\n".join(out))
print("wrote BENCH_summary.md "
      f"({len(rows)} result rows, {len(SECTIONS)} sections)")
EOF

echo "kick-tires OK: BENCH_serve.json + BENCH_summary.md regenerated"
