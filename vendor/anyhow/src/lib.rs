//! Offline vendored substitute for the `anyhow` crate — the API subset
//! the `paca` crate uses (`anyhow!`, `bail!`, `Result`, `Context`,
//! `Error` with `{:#}` chain formatting). The real crate is unavailable
//! in the air-gapped build; this one is dependency-free and keeps the
//! same source-level contract:
//!
//!   * `Error` does NOT implement `std::error::Error` (exactly like the
//!     real anyhow), which is what makes the blanket
//!     `From<E: std::error::Error>` impl coherent.
//!   * `{e}` prints the outermost message; `{e:#}` prints the whole
//!     context chain separated by `: `.

use std::fmt;

pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with an outer context message (used by `Context`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }

    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Mirrors anyhow: any std error converts into Error. (Error itself is
// covered by core's reflexive `From<T> for T`, which is why Error must
// not implement std::error::Error.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(msg)` / `.with_context(|| msg)` on Results (of any
/// Into<Error> error type, including Error itself) and Options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn chain_formats() {
        let e = io_err().context("reading x").unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_option() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
    }
}
