//! Offline stub of the `xla-rs` PJRT bindings (the API subset the
//! `paca` crate uses).
//!
//! The air-gapped image has no `xla_extension` shared library, so this
//! crate implements the *host* half of the API for real — `Literal` is
//! a fully functional typed host buffer (create / shape / raw copy /
//! tuple / first-element) — while the *device* half degrades
//! gracefully: `PjRtClient::cpu()` succeeds (so `Runtime::new` and the
//! manifest-only code paths work), but compiling an HLO module returns
//! a clear error. Code that needs actual artifact execution (training,
//! selftest, the PJRT serve backend) reports that error instead of
//! crashing; everything analytic / host-side runs normally.
//!
//! Swap this directory for the real xla-rs checkout (same dependency
//! key in the workspace Cargo.toml) on a machine with xla_extension to
//! get the full PJRT CPU path back — no source change needed in paca.

use std::borrow::Borrow;
use std::rc::Rc;

#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in this offline build: the stub xla \
         crate has no xla_extension/PJRT backend (vendor the real \
         xla-rs to enable artifact execution)"))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    pub fn size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16
            | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Host element types that can cross the raw-copy boundary.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}

impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

/// A typed host buffer — fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
    /// Non-empty for tuple literals (tuples carry no array shape).
    tuple: Vec<Literal>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType, dims: &[usize],
        data: &[u8]) -> Result<Literal, Error> {
        let n: usize = dims.iter().product();
        if n * ty.size() != data.len() {
            return Err(Error(format!(
                "literal data is {} bytes, shape {dims:?} of {ty:?} \
                 wants {}", data.len(), n * ty.size())));
        }
        Ok(Literal {
            shape: ArrayShape {
                dims: dims.iter().map(|&d| d as i64).collect(),
                ty,
            },
            data: data.to_vec(),
            tuple: Vec::new(),
        })
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            shape: ArrayShape { dims: Vec::new(), ty: ElementType::Pred },
            data: Vec::new(),
            tuple: elems,
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        if !self.tuple.is_empty() {
            return Err(Error("tuple literal has no array shape".into()));
        }
        Ok(self.shape.clone())
    }

    pub fn copy_raw_to<T: NativeType>(
        &self, dst: &mut [T]) -> Result<(), Error> {
        if T::TY != self.shape.ty {
            return Err(Error(format!(
                "copy_raw_to: literal is {:?}, destination wants {:?}",
                self.shape.ty, T::TY)));
        }
        let want = dst.len() * std::mem::size_of::<T>();
        if want != self.data.len() {
            return Err(Error(format!(
                "copy_raw_to: literal has {} bytes, destination {want}",
                self.data.len())));
        }
        // SAFETY: NativeType implementors are plain-old-data scalars
        // with no invalid bit patterns, and the length was checked.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(), dst.as_mut_ptr() as *mut u8, want);
        }
        Ok(())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        if self.tuple.is_empty() {
            return Err(Error("literal is not a tuple".into()));
        }
        Ok(self.tuple)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        if T::TY != self.shape.ty {
            return Err(Error(format!(
                "get_first_element: literal is {:?}, wanted {:?}",
                self.shape.ty, T::TY)));
        }
        if self.data.len() < std::mem::size_of::<T>() {
            return Err(Error("empty literal".into()));
        }
        // SAFETY: length checked; T is plain-old-data (NativeType).
        Ok(unsafe { std::ptr::read_unaligned(self.data.as_ptr() as *const T) })
    }
}

/// Parsed HLO module text. The stub only carries the text through to
/// `compile`, which is where the missing backend is reported.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client handle. Rc-based (deliberately !Send, matching the real
/// bindings' threading constraints so code written against the stub
/// stays correct on the real backend).
#[derive(Clone)]
pub struct PjRtClient {
    _marker: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _marker: Rc::new(()) })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (no xla_extension)".to_string()
    }

    pub fn buffer_from_host_literal(
        &self, _device: Option<usize>,
        lit: &Literal) -> Result<PjRtBuffer, Error> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }

    pub fn compile(
        &self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("HLO compilation"))
    }
}

/// Device buffer — in the stub, a host literal in disguise.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.lit.clone())
    }
}

/// Never constructed in the stub (`compile` always errors); the methods
/// exist so dependent code typechecks identically against real xla-rs.
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        self.client.clone()
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PJRT execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter()
            .flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        let mut out = [0f32; 3];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, vals);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.get_first_element::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[2], &[0u8; 7]).is_err());
    }

    #[test]
    fn tuples() {
        let a = Literal::create_from_shape_and_untyped_data(
            ElementType::S8, &[1], &[7]).unwrap();
        let t = Literal::tuple(vec![a.clone(), a]);
        assert!(t.array_shape().is_err());
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn compile_unavailable_but_client_works() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        assert!(c.compile(&comp).is_err());
    }
}
