//! Property-based tests over the coordinator substrates (hand-rolled
//! harness — proptest is unavailable offline): seeded random cases,
//! failing seed printed on panic.

use paca::config::SchedKind;
use paca::coordinator::merge;
use paca::coordinator::schedule::Schedule;
use paca::memory;
use paca::nf4;
use paca::peft::top_r;
use paca::simulator::{self, A100_80G};
use paca::tensor::{DType, HostTensor};
use paca::util::json::Json;
use paca::util::rng::Rng;

/// Run `f` over `n` seeded cases; report the failing seed.
fn prop(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed ^ 0xdead_beef);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = r {
            panic!("property failed at seed {seed}: {e:?}");
        }
    }
}

fn model_like(rng: &mut Rng) -> paca::manifest::ModelInfo {
    paca::manifest::ModelInfo {
        name: "prop".into(),
        vocab: rng.range(256, 64000),
        d_model: 64 * rng.range(1, 128),
        n_layers: rng.range(1, 96),
        n_heads: 4,
        d_ff: 64 * rng.range(1, 512),
        max_seq: 4096,
        profile_only: true,
    }
}

#[test]
fn prop_schedule_bounded_and_warmup_monotone() {
    prop(200, |rng| {
        let kind = [SchedKind::Constant, SchedKind::Linear,
                    SchedKind::Cosine][rng.below(3)];
        let peak = rng.next_f64() * 0.1 + 1e-6;
        let warm = rng.below(50);
        let total = warm + 1 + rng.below(1000);
        let s = Schedule::new(kind, peak, warm, total);
        let mut prev = 0.0;
        for step in 0..total + 10 {
            let lr = s.lr(step);
            assert!(lr >= -1e-15 && lr <= peak + 1e-12,
                    "lr {lr} outside [0, {peak}]");
            if step < warm {
                assert!(lr >= prev - 1e-15, "warmup must ramp up");
            }
            prev = lr;
        }
    });
}

#[test]
fn prop_memory_monotone_in_batch_seq_rank() {
    prop(100, |rng| {
        let m = model_like(rng);
        let method = ["full", "lora", "dora", "moslora", "paca",
                      "qlora", "qpaca"][rng.below(7)];
        let rank = 1 + rng.below(128);
        let b = 1 + rng.below(32);
        let s = 64 + rng.below(2048);
        let ckpt = rng.below(2) == 0;
        let base = memory::breakdown(&m, method, rank, b, s, ckpt)
            .total();
        assert!(base > 0.0);
        assert!(memory::breakdown(&m, method, rank, b + 1, s, ckpt)
                .total() > base);
        assert!(memory::breakdown(&m, method, rank, b, s + 64, ckpt)
                .total() > base);
        assert!(memory::breakdown(&m, method, rank + 8, b, s, ckpt)
                .total() >= base);
    });
}

#[test]
fn prop_paca_never_worse_than_lora_family() {
    // The paper's core memory claim must hold across the whole design
    // space: PaCA ≤ LoRA ≤ DoRA in total memory, PaCA ≤ LoRA in step
    // time, for ANY model geometry.
    prop(150, |rng| {
        let m = model_like(rng);
        let rank = 1 + rng.below(64);
        let b = 1 + rng.below(16);
        let s = 64 + rng.below(1024);
        let ckpt = rng.below(2) == 0;
        let paca = memory::breakdown(&m, "paca", rank, b, s, ckpt);
        let lora = memory::breakdown(&m, "lora", rank, b, s, ckpt);
        let dora = memory::breakdown(&m, "dora", rank, b, s, ckpt);
        assert!(paca.total() <= lora.total() + 1.0);
        assert!(lora.total() <= dora.total() + 1.0);
        let tp = simulator::iteration_time(&A100_80G, &m, "paca", rank,
                                           b, s).total_s();
        let tl = simulator::iteration_time(&A100_80G, &m, "lora", rank,
                                           b, s).total_s();
        assert!(tp <= tl + 1e-12, "paca {tp} > lora {tl}");
    });
}

#[test]
fn prop_max_seq_consistent_with_breakdown() {
    prop(60, |rng| {
        let m = model_like(rng);
        let method = ["lora", "paca", "dora"][rng.below(3)];
        let cap = 20e9 + rng.next_f64() * 120e9;
        let s = memory::max_seq_len(&m, method, 8, cap, false);
        if s > 0 {
            // fits at the reported max…
            assert!(memory::breakdown(&m, method, 8, 1, s, false)
                    .total() <= cap * 1.001);
            // …and would not fit with a whole extra granule.
            assert!(memory::breakdown(&m, method, 8, 1, s + 200, false)
                    .total() > cap * 0.999);
        }
    });
}

#[test]
fn prop_nf4_roundtrip_bound_any_distribution() {
    let mut max_gap = 0f32;
    for i in 1..16 {
        max_gap = max_gap.max(nf4::NF4_CODEBOOK[i]
                              - nf4::NF4_CODEBOOK[i - 1]);
    }
    prop(100, |rng| {
        let blocks = 1 + rng.below(16);
        let scale_mag = 10f32.powi(rng.range(0, 6) as i32 - 3);
        let w: Vec<f32> = (0..blocks * 64)
            .map(|_| rng.normal_f32(scale_mag)).collect();
        let (codes, scales) = nf4::quantize(&w, 64);
        let deq = nf4::dequantize(&codes, &scales, 64);
        for b in 0..blocks {
            for i in 0..64 {
                let err = (w[b * 64 + i] - deq[b * 64 + i]).abs();
                assert!(err <= scales[b] * max_gap / 2.0
                        + scales[b] * 1e-5 + 1e-20);
            }
        }
    });
}

#[test]
fn prop_top_r_agrees_with_sort() {
    prop(200, |rng| {
        let n = 1 + rng.below(200);
        let r = 1 + rng.below(n);
        let scores: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0))
            .collect();
        let got = top_r(&scores, r);
        assert_eq!(got.len(), r);
        let mut sorted: Vec<f32> = scores.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let worst_chosen = got.iter()
            .map(|&i| scores[i as usize])
            .fold(f32::INFINITY, f32::min);
        assert!(worst_chosen >= sorted[r - 1] - 1e-6);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 8.0),
            3 => {
                let s: String = (0..rng.below(12))
                    .map(|_| char::from_u32(
                        32 + rng.below(90) as u32).unwrap())
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5))
                           .map(|_| random_json(rng, depth - 1))
                           .collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"),
                             random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    prop(300, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_states() {
    prop(40, |rng| {
        let n = 1 + rng.below(10);
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for i in 0..n {
            names.push(format!("t/{i}"));
            let len = 1 + rng.below(200);
            match rng.below(3) {
                0 => tensors.push(HostTensor::from_f32(
                    &[len], (0..len).map(|_| rng.normal_f32(1.0))
                        .collect())),
                1 => tensors.push(HostTensor::from_i32(
                    &[len], (0..len).map(|_| rng.below(1000) as i32)
                        .collect())),
                _ => tensors.push(HostTensor::from_i8(
                    &[len], (0..len).map(|_| rng.below(16) as i8)
                        .collect())),
            }
        }
        let path = std::env::temp_dir().join(format!(
            "paca-prop-{}-{}.ckpt", std::process::id(),
            rng.next_u64()));
        paca::coordinator::checkpoint::save(&path, &names, &tensors)
            .unwrap();
        let (n2, t2) = paca::coordinator::checkpoint::load(&path)
            .unwrap();
        assert_eq!(n2, names);
        for (a, b) in tensors.iter().zip(&t2) {
            assert_eq!(a.data, b.data);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.dtype as u8 as usize,
                       b.dtype as u8 as usize);
        }
        std::fs::remove_file(&path).ok();
    });
}

/// Random 2-D f32 tensor with arbitrary bit patterns in play (normals
/// at several magnitudes, exact zeros, subnormals).
fn random_weight(rng: &mut Rng, rows: usize, cols: usize) -> HostTensor {
    let vals: Vec<f32> = (0..rows * cols).map(|_| match rng.below(8) {
        0 => 0.0,
        1 => f32::MIN_POSITIVE / 2.0, // subnormal
        2 => -rng.normal_f32(1e6),
        _ => rng.normal_f32(1.0),
    }).collect();
    HostTensor::from_f32(&[rows, cols], vals)
}

#[test]
fn prop_splice_unsplice_roundtrips_bit_exact() {
    // The serving registry's contract: splice→unsplice restores the
    // shared frozen base BYTE-identically, for any geometry, any index
    // set, any weight bit patterns.
    prop(150, |rng| {
        let rows = 1 + rng.below(48);
        let cols = 1 + rng.below(24);
        let r = 1 + rng.below(rows);
        let mut w = random_weight(rng, rows, cols);
        let orig = w.data.clone();
        let idx = rng.choice(rows, r);
        let p = random_weight(rng, r, cols);
        let saved = merge::splice_rows(&mut w, &idx, &p).unwrap();
        // Spliced rows carry P; untouched rows are untouched.
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(w.row_f32(i as usize), p.row_f32(k));
        }
        assert_eq!(saved.shape, vec![r, cols]);
        merge::unsplice_rows(&mut w, &idx, &saved).unwrap();
        assert_eq!(w.data, orig, "un-merge must be bit-exact");
    });
}

#[test]
fn prop_sequential_tenant_splices_never_interact() {
    // Two tenants' adapters applied through the swap discipline
    // (splice A → unsplice A → splice B) must leave tenant B's
    // effective weights identical to B applied on the pristine base —
    // for disjoint AND overlapping index sets.
    prop(100, |rng| {
        let rows = 4 + rng.below(40);
        let cols = 1 + rng.below(16);
        let ra = 1 + rng.below(rows);
        let rb = 1 + rng.below(rows);
        let base = random_weight(rng, rows, cols);

        let idx_a = rng.choice(rows, ra);
        let p_a = random_weight(rng, ra, cols);
        // Tenant B: half the cases reuse indices from A (overlap),
        // half draw independently (usually disjoint-ish).
        let idx_b = if rng.below(2) == 0 {
            let mut i = idx_a.clone();
            i.truncate(rb.min(ra));
            i
        } else {
            rng.choice(rows, rb)
        };
        let p_b = random_weight(rng, idx_b.len(), cols);

        // Reference: B directly on the pristine base.
        let mut w_ref = base.clone();
        let g = merge::splice_rows(&mut w_ref, &idx_b, &p_b).unwrap();
        let spliced_ref = w_ref.data.clone();
        merge::unsplice_rows(&mut w_ref, &idx_b, &g).unwrap();
        assert_eq!(w_ref.data, base.data);

        // Swap sequence: A in, A out, B in.
        let mut w = base.clone();
        let ga = merge::splice_rows(&mut w, &idx_a, &p_a).unwrap();
        merge::unsplice_rows(&mut w, &idx_a, &ga).unwrap();
        let gb = merge::splice_rows(&mut w, &idx_b, &p_b).unwrap();
        assert_eq!(w.data, spliced_ref,
                   "tenant A left a trace in tenant B's weights");
        merge::unsplice_rows(&mut w, &idx_b, &gb).unwrap();
        assert_eq!(w.data, base.data);
    });
}

#[test]
fn prop_online_scheduler_reproduces_offline_plan_when_fully_arrived() {
    // The serving refactor's correctness anchor: for ANY queue whose
    // requests have all arrived, the online scheduler's incremental
    // dispatch sequence must equal the offline one-shot plan — same
    // batches, same order, same swap count — for fifo and swap-aware.
    use paca::serve::scheduler::{plan, swap_count, OnlineScheduler,
                                 Policy, Request, TenantId};
    prop(120, |rng| {
        let n_tenants = 1 + rng.below(6);
        let n = 1 + rng.below(60);
        let cap = 1 + rng.below(6);
        let requests: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: TenantId(rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(64),
            decode_tokens: rng.below(16),
            shared_prefix_tokens: 0,
            arrival_s: 0.0,
            deadline_s: if rng.below(2) == 0 {
                f64::INFINITY
            } else {
                rng.next_f64()
            },
        }).collect();
        for policy in [Policy::Fifo, Policy::SwapAware] {
            let offline = plan(requests.clone(), cap, policy);
            let mut sched = OnlineScheduler::new(
                requests.clone(), n_tenants, cap, policy);
            let online = sched.drain_fully_arrived();
            assert!(sched.is_done());
            assert_eq!(online.len(), offline.len(),
                       "{policy:?}: batch count");
            for (a, b) in online.iter().zip(&offline) {
                assert_eq!(a.tenant, b.tenant, "{policy:?}: order");
                let ia: Vec<u64> =
                    a.requests.iter().map(|r| r.id).collect();
                let ib: Vec<u64> =
                    b.requests.iter().map(|r| r.id).collect();
                assert_eq!(ia, ib, "{policy:?}: membership");
            }
            assert_eq!(swap_count(&online), swap_count(&offline),
                       "{policy:?}: swap count");
        }
        // Every policy (slo-aware has no offline equivalent to match,
        // but it must still conserve requests).
        let mut sched = OnlineScheduler::new(
            requests.clone(), n_tenants, cap, Policy::SloAware);
        let served: usize = sched.drain_fully_arrived().iter()
            .map(|b| b.requests.len()).sum();
        assert_eq!(served, n);
    });
}

#[test]
fn prop_online_scheduler_conserves_requests_under_any_arrivals() {
    // Random arrival times, random admission clock walk: every
    // request is dispatched exactly once, never before it arrives.
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId};
    prop(80, |rng| {
        let n_tenants = 1 + rng.below(5);
        let n = 1 + rng.below(50);
        let cap = 1 + rng.below(5);
        let requests: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: TenantId(rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(32),
            decode_tokens: rng.below(16),
            shared_prefix_tokens: 0,
            arrival_s: rng.next_f64() * 2.0,
            deadline_s: 0.05 + rng.next_f64(),
        }).collect();
        let policy = [Policy::Fifo, Policy::SwapAware,
                      Policy::SloAware][rng.below(3)];
        let mut sched = OnlineScheduler::new(requests.clone(),
                                             n_tenants, cap, policy);
        let mut clock = 0.0f64;
        let mut live = None;
        let mut seen: Vec<u64> = Vec::new();
        loop {
            sched.admit(clock);
            if sched.pending_len() == 0 {
                match sched.next_arrival() {
                    Some(t) => {
                        clock = clock.max(t);
                        sched.admit(clock);
                    }
                    None => break,
                }
            }
            let b = sched.dispatch(live, clock).expect("pending work");
            assert!(!b.requests.is_empty());
            assert!(b.requests.len() <= cap);
            for r in &b.requests {
                assert_eq!(r.tenant, b.tenant);
                assert!(r.arrival_s <= clock,
                        "dispatched before arrival");
                seen.push(r.id);
            }
            live = Some(b.tenant);
            // Random virtual service time.
            clock += rng.next_f64() * 0.1;
        }
        assert!(sched.is_done());
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(),
                   "{policy:?}: lost or duplicated requests");
    });
}

#[test]
fn prop_iteration_level_reduces_to_whole_batch_when_prefill_only() {
    // The Serving-v3 reduction anchor, as a property: for ANY
    // fully-arrived prefill-only queue, the iteration-level engine
    // issues EXACTLY the forwards of (a) the whole-batch online
    // engine and (b) the offline `plan` replay — same per-request
    // token counts, same output checksum, same swap count. 25 seeded
    // cases per run.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{plan, OnlineScheduler, Policy,
                                 Request, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(5);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        let requests: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: paca::serve::scheduler::TenantId(
                rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(24),
            decode_tokens: 0, // prefill-only: the reduction regime
            shared_prefix_tokens: 0,
            arrival_s: 0.0,
            deadline_s: if rng.below(2) == 0 {
                f64::INFINITY
            } else {
                0.01 + rng.next_f64() * 0.1
            },
        }).collect();
        for policy in Policy::ALL {
            let mut whole = engine_for(pool.clone());
            let mut sched = OnlineScheduler::new(
                requests.clone(), n_tenants, cap, policy);
            whole.serve_online(&mut sched, clock).unwrap();
            whole.finish().unwrap();

            let mut iter = engine_for(pool.clone());
            let mut sched = OnlineScheduler::new(
                requests.clone(), n_tenants, cap, policy);
            iter.serve_iterative(&mut sched, clock).unwrap();
            iter.finish().unwrap();

            assert_eq!(iter.checksum, whole.checksum,
                       "{policy:?}: checksum");
            assert_eq!(iter.stats.tokens, whole.stats.tokens,
                       "{policy:?}: token counts");
            assert_eq!(iter.stats.swaps, whole.stats.swaps,
                       "{policy:?}: swaps");
            assert_eq!(iter.stats.batches, whole.stats.batches,
                       "{policy:?}: one step per batch");
            assert_eq!(iter.stats.requests, whole.stats.requests,
                       "{policy:?}: requests");

            // And the offline plan replay (fifo/swap-aware only:
            // slo-aware has no offline equivalent, it plans like
            // swap-aware).
            if policy != Policy::SloAware {
                let mut off = engine_for(pool.clone());
                off.serve(&plan(requests.clone(), cap, policy))
                    .unwrap();
                off.finish().unwrap();
                assert_eq!(iter.checksum, off.checksum,
                           "{policy:?}: offline checksum");
                assert_eq!(iter.stats.tokens, off.stats.tokens,
                           "{policy:?}: offline tokens");
                assert_eq!(iter.stats.swaps, off.stats.swaps,
                           "{policy:?}: offline swaps");
            }
        }
    });
}

#[test]
fn prop_scheduler_fuzz_invariants_under_random_traces() {
    // Seeded fuzz over the scheduler–engine pipeline shape: random
    // arrivals, prompts, decode lengths, deadlines, budgets and
    // policies, driven through the iteration-level protocol
    // (dispatch → join_live → step) with random service times.
    // Invariants: every request dispatched exactly once, never before
    // its arrival; batches and joins never mix tenants; request
    // occupancy never exceeds the batch size; per-step token
    // occupancy (prefill prompts + one per decoding slot) never
    // exceeds --max-batch-tokens.
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId};
    prop(120, |rng| {
        let n_tenants = 1 + rng.below(5);
        let n = 1 + rng.below(60);
        let cap = 1 + rng.below(6);
        let max_tok = 24;
        // Budget 0 = unlimited, else ≥ the largest prompt so the
        // strict per-step bound must hold.
        let budget = if rng.below(2) == 0 {
            0
        } else {
            max_tok + rng.below(64)
        };
        let requests: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: TenantId(rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(max_tok),
            decode_tokens: rng.below(12),
            shared_prefix_tokens: 0,
            arrival_s: rng.next_f64() * 2.0,
            deadline_s: if rng.below(3) == 0 {
                f64::INFINITY
            } else {
                0.05 + rng.next_f64()
            },
        }).collect();
        let policy = Policy::ALL[rng.below(3)];
        let mut sched = OnlineScheduler::new(
            requests.clone(), n_tenants, cap, policy);
        sched.max_batch_tokens = budget;
        sched.decode_slack_s = rng.next_f64() * 1e-3;
        sched.swap_penalty_s = rng.next_f64() * 5e-3;

        let mut clock = 0.0f64;
        let mut seen: Vec<u64> = Vec::new();
        // In-flight decode counts, mirroring the engine's slots.
        let mut slots: Vec<(u64, usize)> = Vec::new();
        let mut live: Option<TenantId> = None;
        loop {
            sched.admit(clock);
            if slots.is_empty() {
                if sched.pending_len() == 0 {
                    match sched.next_arrival() {
                        Some(t) => {
                            clock = clock.max(t);
                            sched.admit(clock);
                        }
                        None => break,
                    }
                }
                let Some(b) = sched.dispatch(live, clock) else {
                    break;
                };
                assert!(!b.requests.is_empty());
                assert!(b.requests.len() <= cap, "{policy:?}: cap");
                if budget > 0 {
                    assert!(b.tokens() <= budget,
                            "{policy:?}: dispatch {} tokens over \
                             budget {budget}", b.tokens());
                }
                live = Some(b.tenant);
                let mut step_tokens = 0;
                for r in b.requests {
                    assert_eq!(r.tenant, b.tenant,
                               "{policy:?}: mixed-tenant batch");
                    assert!(r.arrival_s <= clock,
                            "{policy:?}: dispatched before arrival");
                    step_tokens += r.tokens;
                    seen.push(r.id);
                    slots.push((r.id, r.decode_tokens));
                }
                if budget > 0 {
                    assert!(step_tokens <= budget);
                }
            } else {
                let t = live.unwrap();
                let in_flight = slots.len();
                let spare = if budget == 0 {
                    usize::MAX
                } else {
                    budget.saturating_sub(in_flight)
                };
                let free = cap - in_flight;
                let joined = sched.join_live(t, free, spare);
                assert!(joined.len() <= free, "{policy:?}: join cap");
                let mut join_tokens = 0;
                for r in joined {
                    assert_eq!(r.tenant, t,
                               "{policy:?}: join mixed tenants");
                    assert!(r.arrival_s <= clock,
                            "{policy:?}: joined before arrival");
                    join_tokens += r.tokens;
                    seen.push(r.id);
                    slots.push((r.id, r.decode_tokens));
                }
                assert!(slots.len() <= cap);
                // Step occupancy: one token per decoding slot plus
                // the joiners' prefills must fit the budget.
                if budget > 0 {
                    assert!(in_flight + join_tokens <= budget,
                            "{policy:?}: step occupancy {} over \
                             budget {budget}",
                            in_flight + join_tokens);
                }
            }
            // One "step": random subset of slots completes (always at
            // least decrement, so the fuzz terminates).
            let mut i = 0;
            while i < slots.len() {
                if slots[i].1 == 0 || rng.below(3) == 0 {
                    slots.swap_remove(i);
                } else {
                    slots[i].1 -= 1;
                    i += 1;
                }
            }
            clock += rng.next_f64() * 0.05;
        }
        assert!(sched.is_done(), "{policy:?}: drained");
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(),
                   "{policy:?}: lost or duplicated requests");
    });
}

#[test]
fn prop_kv_pressure_never_overcommits_and_emits_exactly_once() {
    // 120-seed fuzz of the paged-KV serving engine: random decode
    // traces under random SMALL block budgets (often smaller than a
    // single request's lifetime cache — the clamped/overflow degrade
    // path), preemption on or off, the PREFIX CACHE on or off over
    // random per-tenant shared-prefix lengths (donation, hits, CoW
    // forks and LRU reclaim all active under pressure), every
    // policy, random step-token budgets. Invariants, across any
    // number of evict/resume cycles:
    //   * the pool never over-commits (peak blocks ≤ --kv-blocks);
    //   * every request completes EXACTLY once (request count and
    //     queueing/TTFT/e2e sample counts all equal n; TPOT samples
    //     equal the decode-carrying request count);
    //   * the engine drains clean — no leaked blocks, no preempted
    //     request stranded un-resumed (`finish()` checks both).
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(120, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        // Per-tenant system-prompt lengths (0 = no sharing): the
        // cache-on runs must keep every invariant with donations,
        // hits, CoW forks and LRU reclaim all active.
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(3) * rng.below(16)).collect();
        let requests: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: TenantId(rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(24),
            decode_tokens: rng.below(16),
            shared_prefix_tokens: 0,
            arrival_s: rng.next_f64(),
            deadline_s: if rng.below(2) == 0 {
                f64::INFINITY
            } else {
                0.02 + rng.next_f64() * 0.2
            },
        }).map(|mut r| {
            // The shared prefix rides in front of the unique draw,
            // like trace synthesis does.
            r.shared_prefix_tokens = prefixes[r.tenant.index()];
            r.tokens += r.shared_prefix_tokens;
            r
        }).collect();
        let decode_reqs = requests.iter()
            .filter(|r| r.decode_tokens > 0).count();
        let kv_blocks = 2 + rng.below(12);
        let block_tokens = 1 + rng.below(12);
        let preempt = rng.below(2) == 0;
        let prefix_cache = rng.below(2) == 0;
        let policy = Policy::ALL[rng.below(3)];
        let mut eng = engine_for(pool);
        eng.configure_kv(kv_blocks, block_tokens, preempt);
        eng.configure_prefix(prefix_cache);
        let mut sched = OnlineScheduler::new(
            requests, n_tenants, cap, policy);
        if rng.below(2) == 1 {
            sched.max_batch_tokens = 24 + rng.below(64);
        }
        eng.serve_iterative(&mut sched, clock).unwrap();
        assert!(sched.is_done(), "{policy:?}: not drained");
        assert!(eng.kv.stats.peak_blocks <= kv_blocks,
                "{policy:?}: over-commit {} > {kv_blocks} blocks",
                eng.kv.stats.peak_blocks);
        assert_eq!(eng.stats.requests as usize, n,
                   "{policy:?}: exactly-once completion");
        assert_eq!(eng.queueing.count("(all)"), n, "{policy:?}");
        assert_eq!(eng.ttft.count("(all)"), n,
                   "{policy:?}: one first token per request");
        assert_eq!(eng.e2e.count("(all)"), n, "{policy:?}");
        assert_eq!(eng.tpot.count("(all)"), decode_reqs,
                   "{policy:?}: one TPOT sample per decode request");
        if !preempt {
            assert_eq!(eng.stats.preemptions, 0,
                       "{policy:?}: drain-only must never evict");
        }
        if !prefix_cache {
            assert_eq!(eng.prefix.stats.lookups, 0,
                       "{policy:?}: off-mode never touches the cache");
        }
        // Hit tokens can never exceed what was ever cacheable.
        assert!(eng.prefix.stats.hit_tokens
                <= eng.stats.prefill_tokens,
                "{policy:?}: cache served more than was prefilled");
        // No leaked blocks, no leaked REFCOUNTS (finish flushes the
        // prefix cache, then runs the pool's free-list reconciliation
        // — a double-share or lost unref anywhere in the
        // share/fork/donate/reclaim paths fails here), and no
        // stranded preempted requests.
        eng.finish().unwrap();
    });
}

#[test]
fn prop_kv_unlimited_reproduces_pr3_iteration_results() {
    // The reduction anchor: `--kv-blocks 0` (the default, unlimited
    // pool) and an ample bounded pool in drain-only mode must both be
    // checksum-/token-/swap-/makespan-identical — i.e. the KV
    // gating/alloc/grow plumbing is provably pass-through whenever
    // capacity never binds, so the PR-3 iteration results are
    // reproduced exactly. 25 seeded decode traces × 3 policies.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(5);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        let requests: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: TenantId(rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(24),
            decode_tokens: rng.below(12),
            shared_prefix_tokens: 0,
            arrival_s: rng.next_f64() * 0.5,
            deadline_s: if rng.below(2) == 0 {
                f64::INFINITY
            } else {
                0.02 + rng.next_f64() * 0.1
            },
        }).collect();
        for policy in Policy::ALL {
            let run = |kv: Option<(usize, usize, bool)>| {
                let mut eng = engine_for(pool.clone());
                if let Some((blocks, bt, preempt)) = kv {
                    eng.configure_kv(blocks, bt, preempt);
                }
                let mut sched = OnlineScheduler::new(
                    requests.clone(), n_tenants, cap, policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                (eng.checksum, eng.stats.tokens, eng.stats.swaps,
                 eng.stats.steps, eng.stats.virtual_s,
                 eng.stats.deadline_misses)
            };
            let unlimited = run(None);
            let ample = run(Some((1_000_000, 16, false)));
            assert_eq!(unlimited, ample,
                       "{policy:?}: an ample bounded pool must be \
                        bit-inert");
        }
    });
}

#[test]
fn prop_prefix_cache_off_is_bit_identical_to_pr4() {
    // THE PR-5 reduction anchor: `--prefix-cache off` must be
    // bit-for-bit the PR-4 iterative engine — checksums, token
    // counts, swaps, steps, makespan, misses, preemptions — for ANY
    // shared-prefix trace, every policy, 25 seeded cases. Proven two
    // ways per case:
    //   * off-mode IGNORES the prefix fields: the same run on the
    //     trace with `shared_prefix_tokens` stripped (which IS a
    //     PR-4-era trace with identical prompts) is identical;
    //   * an unmatched cache is INERT: cache ON over the stripped
    //     trace is identical too (the plumbing adds nothing when
    //     nothing ever matches).
    // And cache ON over the real trace never computes MORE: same
    // requests exactly-once, tokens ≤ the off-mode run.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(40)).collect();
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(24),
                decode_tokens: rng.below(12),
                shared_prefix_tokens: shared,
                arrival_s: rng.next_f64() * 0.5,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        let stripped: Vec<Request> = requests.iter().cloned()
            .map(|mut r| {
                r.shared_prefix_tokens = 0;
                r
            }).collect();
        // Random pool geometry, bounded or not, preempt or drain.
        let kv = if rng.below(2) == 0 {
            Some((4 + rng.below(40), 1 + rng.below(12),
                  rng.below(2) == 0))
        } else {
            None
        };
        for policy in Policy::ALL {
            let run = |reqs: Vec<Request>, cache: bool| {
                let mut eng = engine_for(pool.clone());
                if let Some((blocks, bt, preempt)) = kv {
                    eng.configure_kv(blocks, bt, preempt);
                }
                eng.configure_prefix(cache);
                let mut sched = OnlineScheduler::new(
                    reqs, n_tenants, cap, policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                ((eng.checksum, eng.stats.tokens, eng.stats.swaps,
                  eng.stats.steps, eng.stats.virtual_s,
                  eng.stats.deadline_misses, eng.stats.preemptions),
                 eng.stats.requests)
            };
            let (off, n_off) = run(requests.clone(), false);
            let (off_stripped, _) = run(stripped.clone(), false);
            let (on_stripped, _) = run(stripped.clone(), true);
            assert_eq!(off, off_stripped,
                       "{policy:?}: off-mode must ignore the prefix \
                        fields (PR-4 trace equivalence)");
            assert_eq!(off, on_stripped,
                       "{policy:?}: an unmatched cache must be inert");
            let (on, n_on) = run(requests.clone(), true);
            assert_eq!(n_on, n_off,
                       "{policy:?}: cache on still serves \
                        exactly-once");
            // Token comparison only where it is structural: with an
            // unbounded pool there are no preemption replays, so
            // cache-on computes exactly the off-mode tokens minus
            // the hits. (Bounded runs can preempt differently —
            // different victims, different replay recompute.)
            if kv.is_none() {
                assert!(on.1 <= off.1,
                        "{policy:?}: the cache must never ADD \
                         computed tokens ({} > {})", on.1, off.1);
            }
        }
    });
}

#[test]
fn prop_chunk_zero_is_bit_identical_to_pr6() {
    // THE PR-7 reduction anchor: `--prefill-chunk-tokens 0` with
    // prefetch and cache-aware dispatch off must be bit-for-bit the
    // PR-6 engine — checksum, every deterministic counter, the whole
    // text report — for ANY decode trace, every policy, 25 seeded
    // cases. And a chunk at least as large as every prompt issues the
    // SAME forwards on an unbounded pool (each prefill is one chunk),
    // so only the chunk LEDGER differs, never the service schedule.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(3) * rng.below(16)).collect();
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(24),
                decode_tokens: rng.below(12),
                shared_prefix_tokens: shared,
                arrival_s: rng.next_f64() * 0.5,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        // Random pool geometry, bounded or not, preempt or drain.
        let kv = if rng.below(2) == 0 {
            Some((4 + rng.below(40), 1 + rng.below(12),
                  rng.below(2) == 0))
        } else {
            None
        };
        let budget = if rng.below(2) == 0 { 0 } else {
            32 + rng.below(64)
        };
        for policy in Policy::ALL {
            // explicit: None = the untouched PR-6 engine (no chunk /
            // prefetch / cache-aware calls at all); Some(c) = every
            // PR-7 knob wired the way the CLI wires it, chunk c.
            let run = |explicit: Option<usize>| {
                let mut eng = engine_for(pool.clone());
                if let Some((blocks, bt, preempt)) = kv {
                    eng.configure_kv(blocks, bt, preempt);
                }
                if let Some(chunk) = explicit {
                    eng.configure_chunking(chunk);
                    eng.configure_prefetch(false);
                }
                let mut sched = OnlineScheduler::new(
                    requests.clone(), n_tenants, cap, policy);
                sched.max_batch_tokens = budget;
                if let Some(chunk) = explicit {
                    sched.prefill_chunk_tokens = chunk;
                    sched.cache_aware = false;
                }
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                eng
            };
            let base = run(None);
            let zero = run(Some(0));
            assert_eq!(zero.checksum, base.checksum,
                       "{policy:?}: chunk 0 must not touch forwards");
            assert_eq!(
                (zero.stats.tokens, zero.stats.swaps,
                 zero.stats.steps, zero.stats.virtual_s,
                 zero.stats.deadline_misses, zero.stats.preemptions,
                 zero.stats.prefill_chunks),
                (base.stats.tokens, base.stats.swaps,
                 base.stats.steps, base.stats.virtual_s,
                 base.stats.deadline_misses, base.stats.preemptions,
                 0u64),
                "{policy:?}: chunk 0 must be counter-identical");
            assert_eq!(zero.report(), base.report(),
                       "{policy:?}: chunk 0 must not even change the \
                        report");
            // Oversized chunk on an unbounded pool: one chunk per
            // prefill, same schedule, only the ledger counts.
            if kv.is_none() {
                let huge = run(Some(1 << 20));
                assert_eq!(huge.checksum, base.checksum,
                           "{policy:?}: oversized chunk");
                assert_eq!(
                    (huge.stats.tokens, huge.stats.steps,
                     huge.stats.virtual_s,
                     huge.stats.chunked_prefills),
                    (base.stats.tokens, base.stats.steps,
                     base.stats.virtual_s, 0u64),
                    "{policy:?}: oversized chunk must only ledger");
                assert!(huge.stats.prefill_chunks >= n as u64,
                        "{policy:?}: every prefill step is ledgered");
            }
        }
    });
}

#[test]
fn prop_chunked_prefill_under_kv_pressure_stays_exactly_once() {
    // The chunked extension of the KV-pressure fuzz: random SMALL
    // chunk sizes over random tight pools with preemption FORCED ON
    // and the auditor recording — so mid-prompt slots are routinely
    // evicted between chunks and replayed from token zero. Invariants
    // per seed: the pool never over-commits, every request completes
    // exactly once (one first token, one queueing/e2e sample each),
    // the chunk ledger drains in order (auditor-clean with the new
    // PrefillChunk/PrefillEnd rules), and the engine drains with no
    // leaked blocks or stranded requests. Across the sweep, the
    // paths this PR added must actually fire: prompts split into
    // multiple chunks AND at least one mid-prompt preemption.
    use std::sync::atomic::{AtomicU64, Ordering};

    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::events::Events;
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    static SPLIT_PROMPTS: AtomicU64 = AtomicU64::new(0);
    static MID_PROMPT_PREEMPTS: AtomicU64 = AtomicU64::new(0);

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(120, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(3) * rng.below(12)).collect();
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(5);
        let chunk = 1 + rng.below(8);
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            // Every few requests a LONG prompt, so chunked prefills
            // span many steps while the tight pool squeezes them.
            let long = if id % 4 == 0 { 24 + rng.below(48) } else { 0 };
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(16) + long,
                decode_tokens: rng.below(12),
                shared_prefix_tokens: shared,
                arrival_s: rng.next_f64() * 0.5,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        let decode_reqs = requests.iter()
            .filter(|r| r.decode_tokens > 0).count();
        let kv_blocks = 2 + rng.below(12);
        let block_tokens = 1 + rng.below(8);
        let prefix_cache = rng.below(2) == 0;
        let policy = Policy::ALL[rng.below(3)];
        let mut eng = engine_for(pool);
        eng.configure_kv(kv_blocks, block_tokens, true);
        eng.configure_prefix(prefix_cache);
        eng.configure_chunking(chunk);
        eng.configure_events(Events::recording());
        let mut sched = OnlineScheduler::new(
            requests, n_tenants, cap, policy);
        sched.prefill_chunk_tokens = chunk;
        if rng.below(2) == 1 {
            sched.max_batch_tokens = chunk.max(8) + rng.below(64);
        }
        eng.serve_iterative(&mut sched, clock).unwrap();
        assert!(sched.is_done(), "{policy:?}: not drained");
        assert!(eng.kv.stats.peak_blocks <= kv_blocks,
                "{policy:?}: over-commit {} > {kv_blocks} blocks",
                eng.kv.stats.peak_blocks);
        assert_eq!(eng.stats.requests as usize, n,
                   "{policy:?}: exactly-once completion");
        assert_eq!(eng.ttft.count("(all)"), n,
                   "{policy:?}: one first token per request, \
                    however many chunk/preempt cycles it took");
        assert_eq!(eng.queueing.count("(all)"), n, "{policy:?}");
        assert_eq!(eng.e2e.count("(all)"), n, "{policy:?}");
        assert_eq!(eng.tpot.count("(all)"), decode_reqs,
                   "{policy:?}: one TPOT sample per decode request");
        assert!(eng.stats.prefill_chunks > 0,
                "{policy:?}: chunked mode must ledger every prefill");
        assert_eq!(eng.events.violation_count(), 0,
                   "{policy:?} auditor violations: {:?}",
                   eng.events.violations());
        SPLIT_PROMPTS.fetch_add(eng.stats.chunked_prefills,
                                Ordering::Relaxed);
        MID_PROMPT_PREEMPTS.fetch_add(eng.stats.preempt_prefill,
                                      Ordering::Relaxed);
        eng.finish().unwrap();
    });
    assert!(SPLIT_PROMPTS.load(Ordering::Relaxed) > 0,
            "the sweep never split a prompt into multiple chunks — \
             the fuzz is not exercising chunked prefill");
    assert!(MID_PROMPT_PREEMPTS.load(Ordering::Relaxed) > 0,
            "the sweep never preempted a mid-prompt slot — the \
             resume-from-chunk path went untested");
}

#[test]
fn prop_prefetch_is_inert_without_prefixes_and_conservative_with() {
    // The prefetch satellite's anchor, 25 seeded cases × 3 policies:
    //   * over a trace with NO shared prefixes, prefetch ON is
    //     bit-for-bit OFF (there is nothing to warm, so the idle-gap
    //     scan must never fire a forward or touch the clock);
    //   * over a shared-prefix trace on an unbounded pool, prefetch
    //     still serves exactly-once, and its real (non-speculative)
    //     compute never exceeds the off-mode run — speculative work
    //     only ever REPLACES demand prefill, never adds to it.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| 4 + rng.below(24)).collect();
        let n = 1 + rng.below(30);
        let cap = 1 + rng.below(5);
        // Sparse arrivals leave genuine idle gaps for the prefetcher.
        let bare: Vec<Request> = (0..n as u64).map(|id| Request {
            id,
            tenant: TenantId(rng.below(n_tenants) as u32),
            tokens: 1 + rng.below(24),
            decode_tokens: rng.below(8),
            shared_prefix_tokens: 0,
            arrival_s: id as f64 * (0.01 + rng.next_f64() * 0.05),
            deadline_s: f64::INFINITY,
        }).collect();
        let shared: Vec<Request> = bare.iter().cloned().map(|mut r| {
            r.shared_prefix_tokens = prefixes[r.tenant.index()];
            r.tokens += r.shared_prefix_tokens;
            r
        }).collect();
        for policy in Policy::ALL {
            let run = |reqs: Vec<Request>, prefetch: bool| {
                let mut eng = engine_for(pool.clone());
                // Prefix cache ON in every run (prefetch requires it
                // and config validation enforces that) so the on/off
                // comparison isolates the prefetcher itself.
                eng.configure_prefix(true);
                eng.configure_prefetch(prefetch);
                let mut sched = OnlineScheduler::new(
                    reqs, n_tenants, cap, policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                eng
            };
            let off = run(bare.clone(), false);
            let on = run(bare.clone(), true);
            assert_eq!(on.checksum, off.checksum,
                       "{policy:?}: prefetch over a prefix-free trace \
                        must be bit-inert");
            assert_eq!(
                (on.stats.tokens, on.stats.steps, on.stats.virtual_s,
                 on.stats.prefetch_tokens),
                (off.stats.tokens, off.stats.steps,
                 off.stats.virtual_s, 0u64),
                "{policy:?}: nothing to warm, nothing happens");
            let cold = run(shared.clone(), false);
            let warm = run(shared.clone(), true);
            assert_eq!(warm.stats.requests, cold.stats.requests,
                       "{policy:?}: prefetch still serves \
                        exactly-once");
            assert_eq!(warm.ttft.count("(all)"), n, "{policy:?}");
            assert!(warm.stats.tokens - warm.stats.prefetch_tokens
                    <= cold.stats.tokens,
                    "{policy:?}: speculative work must replace demand \
                     prefill, never add real compute ({} - {} vs {})",
                    warm.stats.tokens, warm.stats.prefetch_tokens,
                    cold.stats.tokens);
        }
    });
}

#[test]
fn prop_rng_choice_uniformity() {
    // Every index should be selected with roughly equal frequency.
    let mut counts = vec![0usize; 32];
    for seed in 0..4000u64 {
        let mut rng = Rng::new(seed);
        for i in rng.choice(32, 8) {
            counts[i as usize] += 1;
        }
    }
    let expected = 4000.0 * 8.0 / 32.0; // = 1000
    for (i, &c) in counts.iter().enumerate() {
        assert!((c as f64 - expected).abs() < expected * 0.15,
                "index {i} chosen {c} times (expected ~{expected})");
    }
}

#[test]
fn prop_tensor_dtype_sizes() {
    prop(50, |rng| {
        let len = 1 + rng.below(100);
        let t = HostTensor::zeros(&[len], DType::F32);
        assert_eq!(t.bytes(), len * 4);
        let t = HostTensor::zeros(&[len, 3], DType::I8);
        assert_eq!(t.bytes(), len * 3);
    });
}

#[test]
fn prop_event_tracing_is_inert_and_spans_match_recorders() {
    // THE PR-6 reduction anchor, two claims over 25 seeded decode
    // traces × 3 policies on the iterative engine:
    //   * tracing ON is bit-inert: checksum and every deterministic
    //     EngineStats counter are identical to the null-sink run, and
    //     the online auditor sees zero invariant violations over the
    //     whole sweep (preemptions, resumes, prefix hits and all);
    //   * the span reconstructor is an independent second opinion
    //     that AGREES EXACTLY: queueing/service/e2e/ttft/tpot
    //     percentiles folded out of the event stream equal the
    //     engine's own LatencyRecorder values as bits (every latency
    //     is a virtual-clock difference, and the events carry the
    //     same stamps the recorders subtracted).
    use paca::manifest::ModelInfo;
    use paca::metrics::LatencyRecorder;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              EngineStats, HostBackend, ServeEngine};
    use paca::serve::events::{span_latencies, Events};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    /// Wall-clock members are measured, not virtual — zero them so
    /// the rest of EngineStats compares bit-for-bit.
    fn scrub(mut s: EngineStats) -> EngineStats {
        s.wall_s = 0.0;
        s.forward_s = 0.0;
        s.swap_s = 0.0;
        s
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(32)).collect();
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        // A bounded pool small enough to preempt on some seeds, so
        // the resume/replay span arithmetic is exercised too.
        let kv_blocks = 24 + rng.below(64);
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(24),
                decode_tokens: rng.below(12),
                shared_prefix_tokens: shared,
                arrival_s: rng.next_f64() * 0.5,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        for policy in Policy::ALL {
            let run = |events: Events| {
                let mut eng = engine_for(pool.clone());
                eng.configure_events(events);
                eng.configure_kv(kv_blocks, 16, true);
                let mut sched = OnlineScheduler::new(
                    requests.clone(), n_tenants, cap, policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                eng
            };
            let plain = run(Events::off());
            let traced = run(Events::recording());
            assert_eq!(scrub(traced.stats), scrub(plain.stats),
                       "{policy:?}: tracing must be bit-inert");
            assert_eq!(traced.checksum, plain.checksum,
                       "{policy:?}: tracing must not touch forwards");
            assert_eq!(traced.events.violation_count(), 0,
                       "{policy:?} violations: {:?}",
                       traced.events.violations());
            let stream = traced.events.snapshot();
            assert_eq!(stream.len() as u64, traced.events.total());
            let lat = span_latencies(&stream, traced.pool.names());
            let pairs: [(&str, &LatencyRecorder,
                         &LatencyRecorder); 5] = [
                ("queueing", &traced.queueing, &lat.queueing),
                ("service", &traced.service, &lat.service),
                ("e2e", &traced.e2e, &lat.e2e),
                ("ttft", &traced.ttft, &lat.ttft),
                ("tpot", &traced.tpot, &lat.tpot),
            ];
            let mut keys: Vec<String> = traced.pool.names().to_vec();
            keys.push("(all)".to_string());
            for (name, rec, span) in pairs {
                for key in &keys {
                    assert_eq!(rec.count(key), span.count(key),
                               "{policy:?} {name}/{key} count");
                    for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
                        assert_eq!(rec.percentile(key, q),
                                   span.percentile(key, q),
                                   "{policy:?} {name}/{key} p{q}");
                    }
                }
            }
        }
    });
}

#[test]
fn prop_cluster_of_one_reduces_to_serve_iterative() {
    // THE cluster reduction anchor, 25 seeded traces × 3 scheduler
    // policies × 3 router policies: a one-replica Cluster IS the
    // single iterative engine — same forward checksum (identical
    // forwards in identical order), same deterministic EngineStats,
    // and the same virtual-clock latency distribution at every
    // quantile. Report STRINGS are deliberately not compared: the
    // engine's aggregate line embeds measured wall time, which no two
    // runs share.
    use paca::manifest::ModelInfo;
    use paca::serve::cluster::Cluster;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              EngineStats, HostBackend, ServeEngine};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::router::RouterPolicy;
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    /// Wall-clock members are measured, not virtual — zero them so
    /// the rest of EngineStats compares bit-for-bit.
    fn scrub(mut s: EngineStats) -> EngineStats {
        s.wall_s = 0.0;
        s.forward_s = 0.0;
        s.swap_s = 0.0;
        s
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(16)).collect();
        let n = 1 + rng.below(35);
        let cap = 1 + rng.below(5);
        let kv_blocks = 16 + rng.below(48);
        let chunk = rng.below(6); // 0 = unchunked
        let mut t = 0.0;
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            t += rng.next_f64() * 0.04;
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(20),
                decode_tokens: rng.below(10),
                shared_prefix_tokens: shared,
                arrival_s: t,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        for policy in Policy::ALL {
            // Baseline: the plain single iterative engine.
            let mut base_eng = engine_for(pool.clone());
            base_eng.configure_kv(kv_blocks, 8, true);
            base_eng.configure_prefix(true);
            base_eng.configure_chunking(chunk);
            let mut sched = OnlineScheduler::new(
                requests.clone(), n_tenants, cap, policy);
            sched.prefill_chunk_tokens = chunk;
            base_eng.serve_iterative(&mut sched, clock).unwrap();
            base_eng.finish().unwrap();
            // With one replica the router is never consulted, so
            // EVERY router policy must yield the identical run.
            for rpolicy in RouterPolicy::ALL {
                let mut eng = engine_for(pool.clone());
                eng.configure_kv(kv_blocks, 8, true);
                eng.configure_prefix(true);
                eng.configure_chunking(chunk);
                let mut csched = OnlineScheduler::new(
                    Vec::new(), n_tenants, cap, policy);
                csched.prefill_chunk_tokens = chunk;
                let mut cl = Cluster::new(
                    vec![(eng, csched)], requests.clone(), rpolicy,
                    cap, None);
                cl.run(clock).unwrap();
                let one = &cl.replicas[0].engine;
                assert_eq!(one.checksum, base_eng.checksum,
                           "{policy:?}/{rpolicy:?}: forwards must be \
                            identical in identical order");
                assert_eq!(scrub(one.stats), scrub(base_eng.stats),
                           "{policy:?}/{rpolicy:?}: stats diverged");
                for (name, a, b) in [
                    ("e2e", &one.e2e, &base_eng.e2e),
                    ("queueing", &one.queueing, &base_eng.queueing),
                    ("ttft", &one.ttft, &base_eng.ttft),
                ] {
                    assert_eq!(a.count("(all)"), b.count("(all)"),
                               "{policy:?}/{rpolicy:?} {name} count");
                    for q in [0.0, 0.25, 0.5, 0.75, 0.99, 1.0] {
                        assert_eq!(a.percentile("(all)", q),
                                   b.percentile("(all)", q),
                                   "{policy:?}/{rpolicy:?} {name} \
                                    p{q}");
                    }
                }
            }
        }
    });
}

#[test]
fn prop_cluster_serves_exactly_once_under_pressure_and_kill() {
    // The cluster fuzz, 120 seeded traces × N ∈ {2, 4} replicas with
    // random router policy, bounded per-replica KV pools tight enough
    // to preempt, and (on half the seeds) a mid-trace replica kill:
    //   * no replica ever over-commits its OWN pool;
    //   * every request completes exactly once cluster-wide — kills,
    //     evacuations and re-dispatches included;
    //   * the merged interleaving passes the ClusterAuditor and every
    //     per-replica online auditor with zero violations;
    //   * every scheduler drains (the dead replica's backlog really
    //     did move);
    // and across the sweep at least one kill actually evacuated work
    // (else the failover path went untested).
    use std::sync::atomic::{AtomicU64, Ordering};

    use paca::manifest::ModelInfo;
    use paca::serve::cluster::Cluster;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::events::Events;
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::router::RouterPolicy;
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    static FAILED_OVER: AtomicU64 = AtomicU64::new(0);

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(120, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(3) * rng.below(10)).collect();
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(4);
        let n_replicas = [2, 4][rng.below(2)];
        let rpolicy = RouterPolicy::ALL[rng.below(3)];
        let policy = Policy::ALL[rng.below(3)];
        // Tight enough to preempt on many seeds — failover then has
        // to move requests that already lost blocks once.
        let kv_blocks = 2 + rng.below(12);
        let block_tokens = 1 + rng.below(8);
        let kill = if rng.below(2) == 0 {
            Some((rng.below(n_replicas),
                  rng.next_f64() * 0.4))
        } else {
            None
        };
        let mut t = 0.0;
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            t += rng.next_f64() * 0.03;
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(16),
                decode_tokens: rng.below(10),
                shared_prefix_tokens: shared,
                arrival_s: t,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        let parts = (0..n_replicas).map(|_| {
            let mut eng = engine_for(pool.clone());
            eng.configure_kv(kv_blocks, block_tokens, true);
            eng.configure_prefix(rng.below(2) == 0);
            eng.configure_events(Events::recording());
            let sched = OnlineScheduler::new(
                Vec::new(), n_tenants, cap, policy);
            (eng, sched)
        }).collect();
        let mut cl = Cluster::new(parts, requests, rpolicy, cap,
                                  kill);
        cl.run(clock).unwrap();
        let label = format!("{rpolicy:?}/{policy:?} x{n_replicas} \
                             kill {kill:?}");
        let served: u64 = cl.replicas.iter()
            .map(|r| r.engine.stats.requests).sum();
        assert_eq!(served, n as u64,
                   "{label}: exactly-once cluster-wide completion");
        let first_tokens: u64 = cl.replicas.iter()
            .map(|r| r.engine.ttft.count("(all)") as u64).sum();
        assert_eq!(first_tokens, n as u64,
                   "{label}: one first token per request, however \
                    many replicas it crossed");
        for (i, rep) in cl.replicas.iter().enumerate() {
            assert!(rep.engine.kv.stats.peak_blocks <= kv_blocks,
                    "{label}: replica {i} over-commit {} > \
                     {kv_blocks}", rep.engine.kv.stats.peak_blocks);
            assert!(rep.sched.is_done(),
                    "{label}: replica {i} not drained");
            assert_eq!(rep.engine.events.violation_count(), 0,
                       "{label}: replica {i} auditor: {:?}",
                       rep.engine.events.violations());
        }
        let audit = cl.audit();
        assert_eq!(audit.violation_count(), 0,
                   "{label}: merged auditor: {:?}",
                   audit.violations());
        if let Some((kr, _)) = kill {
            assert!(!cl.replicas[kr].alive,
                    "{label}: killed replica still alive");
        }
        FAILED_OVER.fetch_add(cl.router.stats.failover,
                              Ordering::Relaxed);
    });
    assert!(FAILED_OVER.load(Ordering::Relaxed) > 0,
            "the sweep never moved a request off a killed replica — \
             the failover path went untested");
}

#[test]
fn prop_live_telemetry_is_inert() {
    // THE PR-9 inertness anchor, 25 seeded decode traces × 3
    // policies: turning the FULL telemetry stack on — streaming
    // JSONL sink, bounded recorder, event-fed metrics registry,
    // per-phase step profiler, SLO burn tracker — leaves every
    // deterministic EngineStats counter and the forward checksum
    // bit-identical to the null-sink run. Observation must never
    // steer the schedule. Each run also cross-checks the telemetry
    // against the engine's own books: the profiler's per-phase
    // virtual attribution sums exactly to the stepped service time,
    // and the burn tracker's settled/missed totals equal the
    // deadline counters.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              EngineStats, HostBackend, ServeEngine};
    use paca::serve::events::Events;
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::telemetry::{JsonlStreamSink, MetricsFeeder,
                                 TelemetryOut};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    fn scrub(mut s: EngineStats) -> EngineStats {
        s.wall_s = 0.0;
        s.forward_s = 0.0;
        s.swap_s = 0.0;
        s
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(4);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let prefixes: Vec<usize> = (0..n_tenants)
            .map(|_| rng.below(32)).collect();
        let n = 1 + rng.below(40);
        let cap = 1 + rng.below(6);
        let kv_blocks = 24 + rng.below(64);
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            let tenant = TenantId(rng.below(n_tenants) as u32);
            let shared = prefixes[tenant.index()];
            Request {
                id,
                tenant,
                tokens: shared + 1 + rng.below(24),
                decode_tokens: rng.below(12),
                shared_prefix_tokens: shared,
                arrival_s: rng.next_f64() * 0.5,
                deadline_s: if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    0.02 + rng.next_f64() * 0.1
                },
            }
        }).collect();
        for policy in Policy::ALL {
            let run = |telemetry: bool| {
                let mut eng = engine_for(pool.clone());
                if telemetry {
                    eng.configure_events(Events::recording());
                    eng.events.stream_to(JsonlStreamSink::new(
                        TelemetryOut::memory(), 16));
                    eng.events.bound_recorder(16);
                    eng.events.configure_metrics(MetricsFeeder::new(
                        &[("policy", policy.name())], pool.names(),
                        0.05, Some(TelemetryOut::memory())));
                    eng.configure_profiler(false);
                } else {
                    eng.configure_events(Events::off());
                }
                eng.configure_kv(kv_blocks, 16, true);
                let mut sched = OnlineScheduler::new(
                    requests.clone(), n_tenants, cap, policy);
                eng.serve_iterative(&mut sched, clock).unwrap();
                eng.finish().unwrap();
                eng
            };
            let plain = run(false);
            let on = run(true);
            assert_eq!(scrub(on.stats), scrub(plain.stats),
                       "{policy:?}: telemetry must be bit-inert");
            assert_eq!(on.checksum, plain.checksum,
                       "{policy:?}: telemetry must not touch \
                        forwards");
            assert_eq!(on.events.violation_count(), 0,
                       "{policy:?} violations: {:?}",
                       on.events.violations());
            assert!(on.events.stream_error().is_none());
            assert!(on.events.metrics_error().is_none());
            assert_eq!(on.events.stream_written(),
                       on.events.total(),
                       "{policy:?}: finalize must flush the whole \
                        stream");
            assert!(on.events.metrics_scrapes() > 0,
                    "{policy:?}: the closing scrape always lands");
            // Profiler partition: no unattributed virtual time.
            let p = on.profiler.as_ref().unwrap();
            let (got, want) = (p.total_virtual(), p.step_virtual_s);
            assert!((got - want).abs() <= 1e-9 * want.max(1.0),
                    "{policy:?}: unattributed step time: {got} vs \
                     {want}");
            // Burn tracker totals ARE the deadline counters.
            let slo = on.events.slo_summary();
            let settled: u64 = slo.iter().map(|t| t.total).sum();
            let missed: u64 = slo.iter().map(|t| t.missed).sum();
            assert_eq!(settled, on.stats.deadline_total,
                       "{policy:?}: burn tracker settle count");
            assert_eq!(missed, on.stats.deadline_misses,
                       "{policy:?}: burn tracker miss count");
        }
    });
}

#[test]
fn prop_streaming_sink_matches_buffered_export_and_counts_drops() {
    // The streaming-sink contract, 25 seeded traces: with a tiny
    // ring + recorder bound, (1) the sink has flushed events to its
    // output BEFORE the run finishes (live tail, not an end-of-run
    // rewrite), (2) the final streamed body is byte-identical to
    // the buffered `to_jsonl` export of an unbounded twin run (same
    // events, same order — the ring only changes WHEN bytes land),
    // (3) the recorder's dropped count is exactly the over-bound
    // emission count — never silent — and (4) the online auditor
    // stays clean on the streamed path.
    use paca::manifest::ModelInfo;
    use paca::serve::engine::{tiny_model, BaseModel, ClockModel,
                              HostBackend, ServeEngine};
    use paca::serve::events::{to_jsonl, Events};
    use paca::serve::registry::{AdapterRegistry, PacaAdapter};
    use paca::serve::scheduler::{OnlineScheduler, Policy, Request,
                                 TenantId, TenantPool};
    use paca::serve::telemetry::{JsonlStreamSink, TelemetryOut};
    use paca::serve::trace;

    fn small() -> ModelInfo {
        ModelInfo { d_model: 16, d_ff: 24, ..tiny_model() }
    }

    fn engine_for(pool: TenantPool) -> ServeEngine {
        let m = small();
        let base = BaseModel::synthetic(&m, 7);
        let mut reg = AdapterRegistry::new(64);
        for name in pool.names() {
            reg.insert(PacaAdapter::synthetic(name, &m, 4, 11));
        }
        ServeEngine::new(base, reg, Box::<HostBackend>::default(),
                         pool)
    }

    let clock = ClockModel::Analytic {
        swap_s: 2e-3, batch_s: 5e-4, token_s: 2e-5,
    };
    prop(25, |rng| {
        let n_tenants = 1 + rng.below(3);
        let mut pool = TenantPool::new();
        for i in 0..n_tenants {
            pool.intern(&trace::tenant_name(i));
        }
        let n = 6 + rng.below(30);
        let requests: Vec<Request> = (0..n as u64).map(|id| {
            Request {
                id,
                tenant: TenantId(rng.below(n_tenants) as u32),
                tokens: 1 + rng.below(24),
                decode_tokens: 1 + rng.below(10),
                shared_prefix_tokens: 0,
                arrival_s: rng.next_f64() * 0.4,
                deadline_s: f64::INFINITY,
            }
        }).collect();
        let cap = 1 + rng.below(12);
        let policy = Policy::ALL[rng.below(3)];
        let run = |bound: Option<usize>| {
            let mut eng = engine_for(pool.clone());
            eng.configure_events(Events::recording());
            if let Some(b) = bound {
                eng.events.stream_to(JsonlStreamSink::new(
                    TelemetryOut::memory(), b));
                eng.events.bound_recorder(b);
            }
            let mut sched = OnlineScheduler::new(
                requests.clone(), n_tenants, 4, policy);
            // Manual step loop so the mid-run flush is observable.
            let mut st = eng.begin_iterative(&mut sched, clock);
            let mut flushed_mid_run = false;
            loop {
                let more = eng.step_iterative(&mut sched, &mut st)
                    .unwrap();
                if more && eng.events.stream_written() > 0 {
                    flushed_mid_run = true;
                }
                if !more {
                    break;
                }
            }
            eng.end_iterative(st);
            eng.finish().unwrap();
            (eng, flushed_mid_run)
        };
        let (unbounded, _) = run(None);
        let twin = unbounded.events.snapshot();
        let (bounded, flushed_mid_run) = run(Some(cap));
        assert!(flushed_mid_run,
                "cap {cap}: the sink never flushed before finish");
        assert!(bounded.events.stream_error().is_none());
        let body = bounded.events.stream_body().unwrap();
        assert_eq!(String::from_utf8(body).unwrap(),
                   to_jsonl(&twin),
                   "cap {cap}: streamed body must equal the \
                    buffered export, byte for byte");
        let total = twin.len() as u64;
        assert_eq!(bounded.events.total(), total,
                   "bounding the recorder must not change emission");
        assert_eq!(bounded.events.events_dropped(),
                   total.saturating_sub(cap as u64),
                   "cap {cap}: drops must be exactly the over-bound \
                    emissions");
        assert_eq!(bounded.events.snapshot().len() as u64,
                   total.min(cap as u64),
                   "cap {cap}: the recorder keeps the FIRST cap");
        assert_eq!(bounded.events.violation_count(), 0,
                   "auditor on the streamed path: {:?}",
                   bounded.events.violations());
        assert_eq!(bounded.checksum, unbounded.checksum,
                   "the bound must be observation-only");
    });
}
