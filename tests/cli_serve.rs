//! End-to-end CLI smoke test: run the built `paca` binary's `serve`
//! subcommand against a tiny synthesized trace in a temp dir and
//! assert it exits 0 with a non-empty report. Uses the host backend,
//! so it needs no artifacts and runs on a fresh checkout.

use std::path::PathBuf;
use std::process::Command;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "paca-cli-{tag}-{}", std::process::id()))
}

#[test]
fn serve_cli_end_to_end() {
    let dir = tmp("serve");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");
    let adapters = dir.join("adapters");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("24")
            .arg("--tenants").arg("3")
            .arg("--batch").arg("4")
            .arg("--mean-tokens").arg("8")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    // First run synthesizes trace + adapters and serves online with
    // SLO scheduling.
    let out = run(&["--policy", "slo-aware", "--deadline-ms", "50",
                    "--burstiness", "2"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "paca serve failed:\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(!stdout.trim().is_empty(), "report must not be empty");
    assert!(stdout.contains("online pipeline"),
            "online metrics missing:\n{stdout}");
    assert!(stdout.contains("deadline misses"),
            "SLO accounting missing:\n{stdout}");
    assert!(stdout.contains("restored bit-exactly"),
            "base-restore check missing:\n{stdout}");
    assert!(trace.exists(), "trace must be persisted");
    assert!(adapters.join("tenant-000.paca").exists(),
            "adapters must be persisted");

    // Second run reloads the persisted trace/adapters (round-trip
    // through JSONL + .paca files) under a different policy.
    let out = run(&["--policy", "fifo"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "reload run failed:\n{stdout}");
    assert!(stdout.contains("loaded 24 requests"),
            "must reuse the persisted trace:\n{stdout}");

    // Bad flags fail loudly, not silently.
    let out = run(&["--policy", "lifo"]);
    assert!(!out.status.success(), "unknown policy must error");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_iteration_level_decode() {
    let dir = tmp("serve-iter");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("decode_trace.jsonl");
    let adapters = dir.join("adapters");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("24")
            .arg("--tenants").arg("3")
            .arg("--batch").arg("4")
            .arg("--mean-tokens").arg("8")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    // First run synthesizes a decode-heavy trace and serves it
    // iteration-level (the default unit) under a step-token budget.
    let out = run(&["--decode-tokens", "8", "--max-batch-tokens", "96",
                    "--policy", "slo-aware", "--deadline-ms", "40",
                    "--burstiness", "2"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "paca serve failed:\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("unit step"),
            "service unit missing from banner:\n{stdout}");
    assert!(stdout.contains("step budget 96 tokens"),
            "budget missing from banner:\n{stdout}");
    // "ttft p99" / "iteration steps" are unique to the engine's
    // iteration-level report (the always-printed cost projection
    // block mentions "iteration-level decode" too, so that string
    // can't discriminate).
    assert!(stdout.contains("ttft p99"),
            "TTFT/TPOT report missing:\n{stdout}");
    assert!(stdout.contains("iteration steps"),
            "occupancy summary missing:\n{stdout}");
    assert!(stdout.contains("restored bit-exactly"),
            "base-restore check missing:\n{stdout}");
    assert!(trace.exists(), "decode trace must be persisted");
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("decode_tokens"),
            "persisted trace must carry decode lengths:\n{text}");

    // Same persisted trace through the v2 whole-batch unit: still
    // works, but no iteration-level decode section.
    let out = run(&["--service-unit", "batch"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "whole-batch run failed:\n{stdout}");
    assert!(stdout.contains("loaded 24 requests"),
            "must reuse the persisted decode trace:\n{stdout}");
    assert!(stdout.contains("unit batch"), "banner:\n{stdout}");
    assert!(!stdout.contains("ttft p99")
            && !stdout.contains("iteration steps"),
            "whole-batch unit must not report TTFT/occupancy:\n\
             {stdout}");

    // Bad unit fails loudly.
    let out = run(&["--service-unit", "token"]);
    assert!(!out.status.success(), "unknown unit must error");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_kv_pressure_preempts_and_drain_only_does_not() {
    // End-to-end KV-pressure smoke: a paged pool far smaller than the
    // in-flight demand (8 × 8-token blocks vs ~32-token lifetime
    // caches) under an effectively fully-arrived queue
    // (--req-per-s 1e9 makes the preemption count independent of the
    // measured host clock — validated by simulation across 5 orders
    // of magnitude of service time). Preemption must actually fire
    // and be visible in the report; the same trace in drain-only mode
    // must serve every request without a single eviction.
    let dir = tmp("serve-kv");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("kv_trace.jsonl");
    let adapters = dir.join("adapters");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("64")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("8")
            .arg("--mean-tokens").arg("16")
            .arg("--decode-tokens").arg("16")
            .arg("--deadline-ms").arg("50")
            .arg("--burstiness").arg("3")
            .arg("--req-per-s").arg("1e9")
            .arg("--policy").arg("slo-aware")
            .arg("--kv-blocks").arg("8")
            .arg("--kv-block-tokens").arg("8")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    let out = run(&[]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "kv-pressure serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("kv pool 8 x 8-token blocks (preempt)"),
            "kv banner missing:\n{stdout}");
    assert!(stdout.contains("kv cache:"),
            "kv occupancy report missing:\n{stdout}");
    assert!(stdout.contains("preemptions:"),
            "preemption counters missing:\n{stdout}");
    assert!(!stdout.contains("preemptions: 0 ("),
            "the tiny pool must force at least one preemption:\n\
             {stdout}");
    assert!(stdout.contains("restored bit-exactly"),
            "base-restore check missing:\n{stdout}");

    // Same persisted trace, drain-only: still serves exactly-once,
    // zero evictions, and says so.
    let out = run(&["--preempt", "false"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "drain-only run failed:\n{stdout}");
    assert!(stdout.contains("loaded 64 requests"),
            "must reuse the persisted trace:\n{stdout}");
    assert!(stdout.contains("(drain-only)"),
            "drain-only banner missing:\n{stdout}");
    assert!(stdout.contains("preemptions: 0 ("),
            "drain-only must never evict:\n{stdout}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");

    // A zero-token block size is rejected up front.
    let out = run(&["--kv-block-tokens", "0"]);
    assert!(!out.status.success(), "kv-block-tokens 0 must error");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_prefix_cache_and_report_json() {
    // Prefix-cache smoke: a shared-prefix trace served with the cache
    // on (the default) must report a NONZERO hit count — with 16
    // same-tenant requests against 4 slots per tenant (batch 8 over 4
    // tenants), later seats structurally follow earlier same-tenant
    // completions, whose donations they hit regardless of the
    // measured host clock. Off-mode must reproduce the PR-4 report
    // shape: same sections, no prefix-cache line. And --report-json
    // must emit the machine-readable counters next to the text.
    let dir = tmp("serve-prefix");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("prefix_trace.jsonl");
    let adapters = dir.join("adapters");
    let report = dir.join("report.json");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("64")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("8")
            .arg("--mean-tokens").arg("8")
            .arg("--decode-tokens").arg("8")
            .arg("--shared-prefix-tokens").arg("48")
            .arg("--req-per-s").arg("1e9")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    let out = run(&["--report-json", report.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "prefix serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    let hit_line = stdout.lines()
        .find(|l| l.starts_with("prefix cache:"))
        .unwrap_or_else(|| panic!("no prefix-cache report:\n{stdout}"));
    assert!(!hit_line.contains(" 0 hits"),
            "shared-prefix trace must actually hit: {hit_line}");
    assert!(hit_line.contains("donated"), "{hit_line}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");
    // The persisted trace carries the prefix field.
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("shared_prefix_tokens"), "{text}");
    // Machine-readable report: parses, and agrees with the text on
    // the basics.
    let json = std::fs::read_to_string(&report).unwrap();
    assert!(json.contains("\"requests\":64"),
            "report json must carry the counters: {json}");
    assert!(json.contains("\"prefix_cache\""), "{json}");
    assert!(json.contains("\"ttft\""), "{json}");
    assert!(json.contains("\"hit_rate\""), "{json}");

    // Same persisted trace, cache off: the PR-4-identical report
    // shape — the iteration-level sections are all there, the
    // prefix-cache line is not.
    let out = run(&["--prefix-cache", "off"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "off-mode run failed:\n{stdout}");
    assert!(stdout.contains("loaded 64 requests"), "{stdout}");
    assert!(stdout.contains("prefix cache off"),
            "banner must say the cache is off:\n{stdout}");
    assert!(stdout.contains("ttft p99"), "{stdout}");
    assert!(stdout.contains("iteration steps"), "{stdout}");
    assert!(!stdout.contains("prefix cache:"),
            "off-mode must not grow a prefix-cache report line:\n\
             {stdout}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");

    // Degenerate flag value fails loudly.
    let out = run(&["--prefix-cache", "maybe"]);
    assert!(!out.status.success(), "bad prefix-cache value must error");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_chunked_prefill_prefetch_and_heavy_tail_traces() {
    // PR-7 smoke, end to end through the binary:
    //   * a heavy-tailed multi-turn chat trace (--prompt-tail +
    //     --chat-turns) synthesizes, persists and RELOADS with the
    //     expanded request count (24 base prompts × 3 turns = 72);
    //   * chunked prefill under a step budget serves it with the
    //     auditor recording and comes back clean, with the chunk cap
    //     in the banner and the chunk ledger in the report;
    //   * the same persisted trace unchunked (the default) grows no
    //     chunk report line — the off-mode stays PR-6-shaped;
    //   * speculative prefetch + cache-aware dispatch over a sparse
    //     shared-prefix trace warms ahead of arrivals: the report's
    //     donation count must be NONZERO (the first idle gap always
    //     precedes the first arrival, so the warm structurally
    //     completes regardless of the measured host clock);
    //   * every degenerate flag combination is rejected up front.
    let dir = tmp("serve-chunk");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("tail_trace.jsonl");
    let adapters = dir.join("adapters");
    let events_path = dir.join("chunk_events.jsonl");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("24")
            .arg("--tenants").arg("3")
            .arg("--batch").arg("4")
            .arg("--mean-tokens").arg("8")
            .arg("--decode-tokens").arg("8")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    // Chunked run over a freshly synthesized heavy-tail chat trace.
    let out = run(&["--prompt-tail", "0.4", "--chat-turns", "3",
                    "--prefill-chunk-tokens", "16",
                    "--max-batch-tokens", "96",
                    "--policy", "slo-aware", "--deadline-ms", "50",
                    "--req-per-s", "1e9",
                    "--trace-events", events_path.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "chunked serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("prefill chunks of 16 tokens"),
            "chunk cap missing from banner:\n{stdout}");
    assert!(stdout.contains("prefill chunks:"),
            "chunk ledger missing from report:\n{stdout}");
    assert!(stdout.contains("auditor: clean"),
            "chunked stream must audit clean:\n{stdout}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");
    // The persisted trace carries the chat expansion: 24 base
    // prompts × 3 turns, follow-ups re-hitting their own context.
    let text = std::fs::read_to_string(&trace).unwrap();
    assert_eq!(text.lines().count(), 72,
               "24 base x 3 turns must persist 72 requests");
    assert!(text.contains("shared_prefix_tokens"),
            "chat turns must carry their context prefix:\n{text}");

    // Reload unchunked: PR-6-shaped report, no chunk line, no
    // chunk events.
    let out = run(&["--policy", "fifo"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "unchunked reload failed:\n{stdout}");
    assert!(stdout.contains("loaded 72 requests"),
            "must reuse the expanded trace:\n{stdout}");
    assert!(!stdout.contains("prefill chunks"),
            "off-mode must not mention chunking:\n{stdout}");
    assert!(stdout.contains("ttft p99"), "{stdout}");

    // Prefetch + cache-aware over a sparse shared-prefix trace (its
    // own trace file: different synthesis knobs).
    let warm_trace = dir.join("warm_trace.jsonl");
    let warm = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&warm_trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("24")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("4")
            .arg("--mean-tokens").arg("8")
            .arg("--decode-tokens").arg("8")
            .arg("--shared-prefix-tokens").arg("48")
            .arg("--req-per-s").arg("5")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };
    let out = warm(&["--prefetch", "on", "--cache-aware", "on"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "prefetch serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("speculative prefix prefetch"),
            "prefetch missing from banner:\n{stdout}");
    assert!(stdout.contains("cache-aware dispatch"),
            "cache-aware missing from banner:\n{stdout}");
    let warm_line = stdout.lines()
        .find(|l| l.starts_with("speculative prefetch:"))
        .unwrap_or_else(|| panic!("no prefetch report:\n{stdout}"));
    assert!(!warm_line.contains(" 0 blocks donated"),
            "idle gaps before arrivals must donate: {warm_line}");
    assert!(!warm_line.starts_with("speculative prefetch: 0 tokens"),
            "{warm_line}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");

    // Degenerate combinations are rejected before serving.
    for (bad, why) in [
        (&["--prefill-chunk-tokens", "128",
           "--max-batch-tokens", "64"][..],
         "chunk larger than the step budget"),
        (&["--prefill-chunk-tokens", "16",
           "--service-unit", "batch"][..],
         "chunking needs iteration-level service"),
        (&["--prefetch", "on", "--prefix-cache", "off"][..],
         "prefetch needs the prefix cache"),
        (&["--prompt-tail", "1.5"][..],
         "tail probability out of range"),
        (&["--prefetch", "maybe"][..], "bad prefetch value"),
        (&["--chat-turns", "-2"][..], "negative chat turns"),
    ] {
        let out = warm(bad);
        assert!(!out.status.success(), "{why}: must error");
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_event_trace_exports_and_audits() {
    // Event-tracing smoke under real pressure: a tiny paged pool with
    // preemption AND a shared-prefix cache, so the exported stream
    // carries the full vocabulary (dispatches, splices, prefix hits,
    // kv alloc/free, preempt/resume). Every JSONL line must parse,
    // the online auditor must come back clean (it would exit nonzero
    // otherwise), the report JSON must carry the schema + events
    // section, and the Chrome export must be one well-formed JSON
    // document.
    use paca::util::json::Json;

    let dir = tmp("serve-events");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("events_trace.jsonl");
    let adapters = dir.join("adapters");
    let events_path = dir.join("events.jsonl");
    let chrome_path = dir.join("events.chrome.json");
    let report = dir.join("report.json");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("48")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("8")
            .arg("--mean-tokens").arg("12")
            .arg("--decode-tokens").arg("12")
            .arg("--shared-prefix-tokens").arg("32")
            .arg("--deadline-ms").arg("50")
            .arg("--burstiness").arg("3")
            .arg("--req-per-s").arg("1e9")
            .arg("--policy").arg("slo-aware")
            .arg("--kv-blocks").arg("16")
            .arg("--kv-block-tokens").arg("8")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    let out = run(&["--trace-events", events_path.to_str().unwrap(),
                    "--report-json", report.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "traced serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("auditor: clean"),
            "auditor verdict missing:\n{stdout}");
    assert!(stdout.contains("event trace:"),
            "event summary missing from report:\n{stdout}");

    // Every exported line is a standalone JSON event with the core
    // stamps; the stream covers the run's whole vocabulary.
    let text = std::fs::read_to_string(&events_path).unwrap();
    let mut kinds = std::collections::HashSet::new();
    let mut n_lines = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(
            |e| panic!("bad event line {line:?}: {e}"));
        for key in ["t_s", "step", "kind", "a", "b"] {
            assert!(j.get(key).is_some(), "{key} missing in {line}");
        }
        kinds.insert(j.get("kind").unwrap().as_str().unwrap()
                     .to_string());
        n_lines += 1;
    }
    assert!(n_lines > 100, "expected a dense stream, got {n_lines}");
    for kind in ["arrival", "admit", "dispatch", "splice_in",
                 "splice_out", "prefill_start", "prefill_end",
                 "decode_step", "complete", "kv_alloc", "kv_free"] {
        assert!(kinds.contains(kind),
                "no {kind} in stream: {kinds:?}");
    }

    // The report JSON grew the schema version and the events section.
    let rj = Json::parse(&std::fs::read_to_string(&report).unwrap())
        .unwrap();
    assert_eq!(rj.get("schema").and_then(|v| v.as_f64()), Some(2.0));
    let ev = rj.get("events").expect("events section in report json");
    assert_eq!(ev.get("auditor").and_then(|v| v.as_str()),
               Some("clean"));
    assert_eq!(ev.get("total").and_then(|v| v.as_f64()),
               Some(n_lines as f64));

    // Chrome export over the same persisted trace: one well-formed
    // JSON document with a traceEvents array.
    let out = run(&["--trace-events", chrome_path.to_str().unwrap(),
                    "--trace-format", "chrome"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "chrome run failed:\n{stdout}");
    assert!(stdout.contains("loaded 48 requests"), "{stdout}");
    let cj = Json::parse(&std::fs::read_to_string(&chrome_path)
                         .unwrap()).unwrap();
    match cj.get("traceEvents") {
        Some(Json::Arr(evs)) => assert!(
            !evs.is_empty(), "empty chrome traceEvents"),
        other => panic!("traceEvents must be an array, got {other:?}"),
    }

    // Bad format fails loudly.
    let out = run(&["--trace-events", events_path.to_str().unwrap(),
                    "--trace-format", "xml"]);
    assert!(!out.status.success(), "unknown trace format must error");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_cluster_replicas_router_and_failover() {
    // Cluster smoke, end to end through the binary:
    //   * --replicas 4 under a flash-crowd arrival pattern serves the
    //     whole trace and reports the cluster block (per-replica
    //     lines, router counters, merged latency percentiles) plus a
    //     clean merged auditor and a JSONL stream whose every line
    //     carries its replica;
    //   * --kill-replica mid-run still completes every request
    //     exactly once (the merged auditor enforces it), reports a
    //     NONZERO failover count and marks the dead replica — with
    //     --req-per-s 1e9 the whole trace is backlogged across the
    //     replicas when the 0.1ms kill point arrives, so work to
    //     evacuate structurally exists regardless of the measured
    //     host clock;
    //   * the cluster report json carries replicas/alive/router;
    //   * every degenerate cluster flag combination is rejected up
    //     front.
    use paca::util::json::Json;

    let dir = tmp("serve-cluster");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("cluster_trace.jsonl");
    let adapters = dir.join("adapters");
    let events_path = dir.join("cluster_events.jsonl");
    let report = dir.join("cluster_report.json");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("64")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("4")
            .arg("--mean-tokens").arg("16")
            .arg("--decode-tokens").arg("16")
            .arg("--req-per-s").arg("1e9")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    // Four replicas, least-loaded routing, flash-crowd synthesis.
    let out = run(&["--replicas", "4", "--router", "least-loaded",
                    "--arrival-pattern", "flash",
                    "--trace-events", events_path.to_str().unwrap(),
                    "--report-json", report.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "cluster serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("4 replicas (router least-loaded"),
            "cluster banner missing:\n{stdout}");
    assert!(stdout.contains("flash arrivals"),
            "arrival pattern missing from banner:\n{stdout}");
    assert!(stdout.contains("cluster: 4 replicas"),
            "cluster report block missing:\n{stdout}");
    assert!(stdout.contains("replica 0:")
            && stdout.contains("replica 3:"),
            "per-replica lines missing:\n{stdout}");
    assert!(stdout.contains("merged ttft"),
            "merged latency summary missing:\n{stdout}");
    assert!(stdout.contains("cluster makespan"), "{stdout}");
    assert!(stdout.contains("auditor: clean"),
            "merged stream must audit clean:\n{stdout}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");
    assert!(stdout.contains("cluster queueing"),
            "cluster cost projection missing:\n{stdout}");
    // Every exported line parses and names its replica — the field
    // only the cluster (replicas > 1) export carries.
    let text = std::fs::read_to_string(&events_path).unwrap();
    assert!(text.lines().count() > 100, "expected a dense stream");
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(
            |e| panic!("bad cluster event line {line:?}: {e}"));
        assert!(j.get("replica").is_some(),
                "replica field missing in {line}");
    }
    // The cluster report json: per-replica reports, liveness and the
    // router's counters.
    let rj = Json::parse(&std::fs::read_to_string(&report).unwrap())
        .unwrap();
    match rj.get("replicas") {
        Some(Json::Arr(reps)) => assert_eq!(reps.len(), 4),
        other => panic!("replicas must be an array, got {other:?}"),
    }
    assert!(rj.get("alive").is_some(), "alive section missing");
    let router = rj.get("router").expect("router section");
    assert!(router.get("failover").is_some());

    // Kill replica 1 at 0.1ms of virtual time: with every request
    // already backlogged, its queue must move to the survivors and
    // every request still completes exactly once (the auditor would
    // fail the run otherwise).
    let out = run(&["--replicas", "4", "--router", "least-loaded",
                    "--kill-replica", "1@0.0001",
                    "--trace-events", events_path.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "kill-replica serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("loaded 64 requests"),
            "must reuse the persisted trace:\n{stdout}");
    assert!(stdout.contains("replica 1 [killed]:"),
            "dead replica must be marked:\n{stdout}");
    let failover_line = stdout.lines()
        .find(|l| l.starts_with("router:"))
        .unwrap_or_else(|| panic!("no router counters:\n{stdout}"));
    assert!(!failover_line.contains("failover: 0"),
            "the kill must actually move work: {failover_line}");
    assert!(stdout.contains("auditor: clean"),
            "failover must stay exactly-once:\n{stdout}");

    // Chrome cluster export: one well-formed document.
    let chrome_path = dir.join("cluster_events.chrome.json");
    let out = run(&["--replicas", "2",
                    "--trace-events", chrome_path.to_str().unwrap(),
                    "--trace-format", "chrome"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "chrome cluster run failed:\n\
                                   {stdout}");
    let cj = Json::parse(&std::fs::read_to_string(&chrome_path)
                         .unwrap()).unwrap();
    match cj.get("traceEvents") {
        Some(Json::Arr(evs)) => assert!(
            !evs.is_empty(), "empty chrome traceEvents"),
        other => panic!("traceEvents must be an array, got {other:?}"),
    }

    // Degenerate cluster flags are rejected before serving.
    for (bad, why) in [
        (&["--replicas", "0"][..], "zero replicas"),
        (&["--replicas", "2", "--service-unit", "batch"][..],
         "clusters need iteration-level service"),
        (&["--router", "warmth", "--replicas", "2",
           "--prefix-cache", "off"][..],
         "warmth routing needs the prefix cache"),
        (&["--kill-replica", "1@0.1"][..],
         "kill-replica needs --replicas > 1"),
        (&["--replicas", "2", "--kill-replica", "5@0.1"][..],
         "kill target out of range"),
        (&["--replicas", "2", "--kill-replica", "1-0.1"][..],
         "malformed kill spec"),
        (&["--router", "round-robin", "--replicas", "2"][..],
         "unknown router"),
        (&["--arrival-pattern", "sawtooth"][..],
         "unknown arrival pattern"),
    ] {
        let out = run(bad);
        assert!(!out.status.success(), "{why}: must error");
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_live_telemetry_metrics_profile_and_slo_burn() {
    // PR-9 smoke, end to end through the binary:
    //   * --trace-events with a small --trace-buffer-events streams
    //     the JSONL during the run (the report line says how much of
    //     the stream lives past the recorder bound, never silently);
    //   * --metrics scrapes the event-fed Prometheus registry — the
    //     file is "# scrape" blocks of counters/gauges/histograms
    //     with tenant/policy labels;
    //   * --profile writes folded stacks with one line per phase
    //     (plus wall duals — the CLI serves on the measured clock);
    //   * the text report grows the step-profile table and the slo
    //     burn block, and the report json carries schema 2 with the
    //     gated metrics section;
    //   * the same serve WITHOUT telemetry flags grows none of it;
    //   * every degenerate flag combination is rejected up front.
    use paca::util::json::Json;

    let dir = tmp("serve-telemetry");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("telemetry_trace.jsonl");
    let adapters = dir.join("adapters");
    let events_path = dir.join("events.jsonl");
    let metrics_path = dir.join("metrics.prom");
    let profile_path = dir.join("profile.folded");
    let report = dir.join("report.json");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("48")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("8")
            .arg("--mean-tokens").arg("12")
            .arg("--decode-tokens").arg("12")
            .arg("--shared-prefix-tokens").arg("32")
            .arg("--deadline-ms").arg("50")
            .arg("--burstiness").arg("3")
            .arg("--req-per-s").arg("1e9")
            .arg("--policy").arg("slo-aware")
            .arg("--kv-blocks").arg("16")
            .arg("--kv-block-tokens").arg("8")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    let out = run(&["--trace-events", events_path.to_str().unwrap(),
                    "--trace-buffer-events", "64",
                    "--metrics", metrics_path.to_str().unwrap(),
                    "--metrics-interval", "0.0005",
                    "--profile", profile_path.to_str().unwrap(),
                    "--report-json", report.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "telemetry serve failed:\nstdout:\n{stdout}\nstderr:\n\
             {stderr}");
    assert!(stdout.contains("auditor: clean"),
            "streamed path must audit clean:\n{stdout}");
    assert!(stdout.contains("recorder bound (streamed to disk"),
            "a 64-event bound must overflow visibly:\n{stdout}");
    assert!(stdout.contains("metric scrapes"),
            "metrics summary line missing:\n{stdout}");
    assert!(stdout.contains("folded step profile"),
            "profile summary line missing:\n{stdout}");
    assert!(stdout.contains("step profile:"),
            "step-profile table missing from report:\n{stdout}");
    assert!(stdout.contains("slo burn"),
            "slo burn block missing from report:\n{stdout}");
    assert!(stdout.contains("restored bit-exactly"), "{stdout}");

    // The streamed JSONL is the full event file (every line parses —
    // the recorder bound changes what stays in MEMORY, not on disk).
    let text = std::fs::read_to_string(&events_path).unwrap();
    let n_lines = text.lines().count();
    assert!(n_lines > 100, "expected a dense stream, got {n_lines}");
    for line in text.lines() {
        Json::parse(line).unwrap_or_else(
            |e| panic!("bad event line {line:?}: {e}"));
    }

    // The metrics file: scrape blocks of Prometheus text with the
    // expected census and labels, counters parse as numbers.
    let prom = std::fs::read_to_string(&metrics_path).unwrap();
    assert!(prom.contains("# scrape "), "no scrape headers:\n{prom}");
    assert!(!prom.contains("NaN"), "NaN leaked into metrics:\n{prom}");
    for name in ["paca_events_total",
                 "paca_requests_arrived_total",
                 "paca_requests_completed_total",
                 "paca_tokens_decoded_total",
                 "paca_e2e_seconds", "paca_ttft_seconds",
                 "paca_kv_used_blocks",
                 "paca_slo_completions_total"] {
        assert!(prom.contains(name), "{name} missing:\n{prom}");
    }
    assert!(prom.contains("policy=\"slo-aware\""),
            "policy base label missing:\n{prom}");
    assert!(prom.contains("tenant=\"tenant-000\""),
            "tenant label missing:\n{prom}");
    assert!(prom.contains("_bucket{"),
            "histogram buckets missing:\n{prom}");
    for line in prom.lines() {
        if line.starts_with("paca_events_total{") {
            let (_, v) = line.rsplit_once(' ').unwrap();
            v.parse::<f64>().unwrap_or_else(
                |e| panic!("bad sample {line:?}: {e}"));
        }
    }

    // The folded stacks: every phase present, every count a whole
    // number of microseconds, wall duals armed on the measured clock.
    let folded = std::fs::read_to_string(&profile_path).unwrap();
    for phase in ["admission", "dispatch", "prefill", "decode",
                  "kv_grow", "prefix", "router"] {
        assert!(folded.contains(&format!(";{phase} ")),
                "{phase} missing from folded stacks:\n{folded}");
    }
    for line in folded.lines() {
        let (stack, v) = line.rsplit_once(' ').unwrap_or_else(
            || panic!("bad folded line {line:?}"));
        assert!(stack.contains(';'), "no stack in {line:?}");
        v.parse::<u64>().unwrap_or_else(
            |e| panic!("bad folded value {line:?}: {e}"));
    }
    assert!(folded.contains("paca_serve_wall;"),
            "measured clock must arm wall duals:\n{folded}");

    // Report json: schema 2, the gated metrics section, and the
    // registry snapshot inside it.
    let rj = Json::parse(&std::fs::read_to_string(&report).unwrap())
        .unwrap();
    assert_eq!(rj.get("schema").and_then(|v| v.as_f64()), Some(2.0));
    let m = rj.get("metrics").expect("metrics section in report json");
    assert!(m.get("events_dropped").is_some());
    assert!(m.get("registry").is_some(), "registry snapshot missing");
    assert!(m.get("profiler").is_some(), "profiler totals missing");
    assert!(m.get("slo_burn").is_some(), "slo burn missing");

    // Telemetry off: none of it appears — the report stays PR-8
    // shaped and no metrics section is emitted.
    let out = run(&["--report-json", report.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "plain reload failed:\n{stdout}");
    assert!(stdout.contains("loaded 48 requests"), "{stdout}");
    assert!(!stdout.contains("step profile:")
            && !stdout.contains("slo burn")
            && !stdout.contains("metric scrapes"),
            "telemetry off must leave no trace in the report:\n\
             {stdout}");
    let rj = Json::parse(&std::fs::read_to_string(&report).unwrap())
        .unwrap();
    assert_eq!(rj.get("schema").and_then(|v| v.as_f64()), Some(2.0));
    assert!(rj.get("metrics").is_none(),
            "metrics section must be gated on tracing");

    // Degenerate flag combinations are rejected before serving.
    for (bad, why) in [
        (&["--trace-events", "e.jsonl",
           "--trace-buffer-events", "0"][..],
         "a 0-event ring can never flush"),
        (&["--trace-events", "e.jsonl", "--metrics", "m.prom",
           "--metrics-interval", "0"][..],
         "zero scrape interval"),
        (&["--metrics", "m.prom"][..],
         "metrics without the event bus"),
        (&["--profile", "p.folded"][..],
         "profile without the event bus"),
    ] {
        let out = run(bad);
        assert!(!out.status.success(), "{why}: must error");
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_cluster_merged_metrics_and_profile() {
    // Cluster telemetry smoke: --replicas 2 with --metrics/--profile
    // merges the per-replica registries under replica labels into
    // ONE scrape file on the merged clock, and folds both engines'
    // profiles (plus the router's own phase) into one stacks file.
    use paca::util::json::Json;

    let dir = tmp("serve-cluster-telemetry");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("cluster_tel_trace.jsonl");
    let adapters = dir.join("adapters");
    let events_path = dir.join("events.jsonl");
    let metrics_path = dir.join("metrics.prom");
    let profile_path = dir.join("profile.folded");
    let report = dir.join("report.json");
    let run = |extra: &[&str]| {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_paca"));
        cmd.arg("serve")
            .arg("--backend").arg("host")
            .arg("--requests").arg(&trace)
            .arg("--adapters").arg(&adapters)
            .arg("--count").arg("48")
            .arg("--tenants").arg("4")
            .arg("--batch").arg("4")
            .arg("--mean-tokens").arg("12")
            .arg("--decode-tokens").arg("12")
            .arg("--deadline-ms").arg("50")
            .arg("--req-per-s").arg("1e9")
            .arg("--replicas").arg("2")
            .arg("--router").arg("least-loaded")
            .args(extra);
        cmd.output().expect("spawning paca serve")
    };

    let out = run(&["--trace-events", events_path.to_str().unwrap(),
                    "--metrics", metrics_path.to_str().unwrap(),
                    "--metrics-interval", "0.0005",
                    "--profile", profile_path.to_str().unwrap(),
                    "--report-json", report.to_str().unwrap()]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "cluster telemetry serve failed:\nstdout:\n{stdout}\n\
             stderr:\n{stderr}");
    assert!(stdout.contains("auditor: clean"), "{stdout}");
    assert!(stdout.contains("merged metric scrapes"),
            "merged scrape summary missing:\n{stdout}");
    assert!(stdout.contains("merged folded step profile"),
            "merged profile summary missing:\n{stdout}");
    assert!(stdout.contains("merged step profile"),
            "merged profile table missing from report:\n{stdout}");

    // Replica labels keep the merged series apart.
    let prom = std::fs::read_to_string(&metrics_path).unwrap();
    assert!(prom.contains("# scrape "), "no scrape headers:\n{prom}");
    assert!(prom.contains("replica=\"0\"")
            && prom.contains("replica=\"1\""),
            "replica labels missing from merged scrape:\n{prom}");
    assert!(!prom.contains("NaN"), "{prom}");

    // The merged folded stacks include the router phase the single
    // engine never exercises.
    let folded = std::fs::read_to_string(&profile_path).unwrap();
    let router_line = folded.lines()
        .find(|l| l.starts_with("paca_serve;step;router "))
        .unwrap_or_else(|| panic!("no router phase:\n{folded}"));
    let (_, v) = router_line.rsplit_once(' ').unwrap();
    v.parse::<u64>().unwrap();

    // Cluster report json: schema intact plus the merged metrics
    // section.
    let rj = Json::parse(&std::fs::read_to_string(&report).unwrap())
        .unwrap();
    let m = rj.get("metrics").expect("merged metrics in report json");
    assert!(m.get("registry").is_some());
    assert!(m.get("profiler").is_some());

    std::fs::remove_dir_all(&dir).ok();
}
