//! Integration tests over the full stack: manifest → init → PJRT
//! execution → update semantics → checkpointing. Require artifacts
//! (`make artifacts`); the PJRT client is shared across tests.

use std::cell::OnceCell;
use std::rc::Rc;

use paca::config::TrainConfig;
use paca::coordinator::Trainer;
use paca::init;
use paca::peft::Selection;
use paca::runtime::Runtime;

// The xla PJRT client is Rc-based (!Send), so each test thread builds
// its own runtime (compilation of the tiny graphs is fast and cached
// within a thread).
/// xla_extension 0.5.1 misbehaves with multiple PJRT CPU clients used
/// concurrently in one process, so integration tests run serialized.
fn serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// None on a fresh checkout (no `make artifacts` yet) — tests skip
/// with a note instead of failing, so tier-1 `cargo test -q` stays
/// meaningful without the lowered artifacts.
fn rt() -> Option<Rc<Runtime>> {
    thread_local! {
        static RT: OnceCell<Option<Rc<Runtime>>> =
            const { OnceCell::new() };
    }
    RT.with(|c| {
        c.get_or_init(|| {
            let dir = paca::default_artifacts_dir();
            if !Runtime::artifacts_present(&dir) {
                return None;
            }
            Some(Rc::new(Runtime::new(&dir)
                         .expect("manifest present but runtime failed")))
        }).clone()
    })
}

/// Evaluates to the shared Runtime, or returns early (skipping the
/// test body) when artifacts are absent.
macro_rules! require_artifacts {
    () => {
        match rt() {
            Some(r) => r,
            None => {
                eprintln!(
                    "skipping integration test: artifacts/manifest.json \
                     not found — run `make artifacts` first");
                return;
            }
        }
    };
}

fn cfg(artifact: &str, steps: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.artifact = artifact.into();
    c.steps = steps;
    c.warmup_steps = 2;
    c.peak_lr = 2e-3;
    c
}

#[test]
fn manifest_lists_all_core_artifacts() {
    let _serial = serial();
    let r = require_artifacts!();
    let m = &r.manifest;
    for name in ["train_full_tiny", "train_lora_tiny", "train_dora_tiny",
                 "train_moslora_tiny", "train_paca_tiny",
                 "train_qlora_tiny", "train_qpaca_tiny", "eval_lm_tiny",
                 "train_paca_vit_tiny", "train_paca_cnn_tiny",
                 "grad_probe_tiny", "kernel_paca_grad"] {
        assert!(m.artifacts.contains_key(name), "{name} missing");
    }
}

#[test]
fn every_method_trains_and_loss_decreases() {
    let _serial = serial();
    let r = require_artifacts!();
    for artifact in ["train_full_tiny", "train_lora_tiny",
                     "train_paca_tiny", "train_qpaca_tiny"] {
        let mut tr = Trainer::new(&r, cfg(artifact, 12)).unwrap();
        tr.run(false).unwrap();
        let first = tr.curve.loss[0];
        let last = tr.curve.tail_mean(3);
        assert!(last < first, "{artifact}: {first} -> {last}");
    }
}

#[test]
fn paca_updates_only_selected_rows() {
    let _serial = serial();
    let r = require_artifacts!();
    let mut tr = Trainer::new(&r, cfg("train_paca_tiny", 3)).unwrap();
    let w0 = tr.state_tensor("blocks/0/q/w").unwrap();
    let idx = tr.state_tensor("blocks/0/q/idx").unwrap();
    tr.run(false).unwrap();
    let w1 = tr.state_tensor("blocks/0/q/w").unwrap();
    let (rows, cols) = (w0.shape[0], w0.shape[1]);
    let selected: std::collections::HashSet<i32> =
        idx.as_i32().into_iter().collect();
    let (a, b) = (w0.as_f32(), w1.as_f32());
    for r in 0..rows {
        let changed = (0..cols).any(|c| a[r * cols + c] != b[r * cols + c]);
        if selected.contains(&(r as i32)) {
            assert!(changed, "selected row {r} did not train");
        } else {
            assert!(!changed, "frozen row {r} changed");
        }
    }
}

#[test]
fn lora_frozen_weight_is_never_touched() {
    let _serial = serial();
    let r = require_artifacts!();
    let mut tr = Trainer::new(&r, cfg("train_lora_tiny", 3)).unwrap();
    let w0 = tr.state_tensor("blocks/1/gate/w").unwrap();
    tr.run(false).unwrap();
    let w1 = tr.state_tensor("blocks/1/gate/w").unwrap();
    assert_eq!(w0.data, w1.data);
    // …while the adapters DID train.
    let b0 = tr.state_tensor("blocks/1/gate/b").unwrap();
    assert!(b0.as_f32().iter().any(|&v| v != 0.0),
            "lora B should have moved off zero-init");
}

#[test]
fn eval_is_deterministic_and_category_sensitive() {
    let _serial = serial();
    let mut c = cfg("train_paca_tiny", 2);
    c.task = "mmlu-like".into();
    let r = require_artifacts!();
    let mut tr = Trainer::new(&r, c).unwrap();
    tr.run(false).unwrap();
    let e1 = tr.evaluate(2).unwrap();
    let e2 = tr.evaluate(2).unwrap();
    assert_eq!(e1.loss, e2.loss, "eval must be deterministic");
    assert_eq!(e1.categories.len(), 4);
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let _serial = serial();
    let dir = std::env::temp_dir();
    let path = dir.join(format!("paca-int-{}.ckpt", std::process::id()));
    let r = require_artifacts!();
    let mut tr = Trainer::new(&r, cfg("train_paca_tiny", 4)).unwrap();
    tr.run(false).unwrap();
    tr.save_checkpoint(&path).unwrap();
    let after_w = tr.state_tensor("blocks/0/v/w").unwrap();

    let mut tr2 = Trainer::new(&r, cfg("train_paca_tiny", 4)).unwrap();
    tr2.load_checkpoint(&path).unwrap();
    assert_eq!(tr2.state_tensor("blocks/0/v/w").unwrap().data,
               after_w.data);
    assert_eq!(tr2.step, tr.step);
    // Resumed trainer can keep training.
    let (loss, _) = tr2.train_step().unwrap();
    assert!(loss.is_finite());
    std::fs::remove_file(&path).ok();
}

#[test]
fn selection_strategies_change_the_index_sets() {
    let _serial = serial();
    let r = require_artifacts!();
    let art = r.manifest.artifact("train_paca_tiny").unwrap();
    let rnd = init::init_state(art, 42, &Selection::Random).unwrap();
    let wn = init::init_state(art, 42, &Selection::WeightNorm).unwrap();
    let idx_pos = art.state.iter().position(|e| e.name == "blocks/0/q/idx")
        .unwrap();
    assert_ne!(rnd[idx_pos].as_i32(), wn[idx_pos].as_i32());
    // Weight tensors themselves must be identical across strategies.
    let w_pos = art.state.iter().position(|e| e.name == "blocks/0/q/w")
        .unwrap();
    assert_eq!(rnd[w_pos].data, wn[w_pos].data);
}

#[test]
fn grad_probe_scores_have_right_shapes() {
    let _serial = serial();
    let r = require_artifacts!();
    let scores = paca::exps::grad_scores(&r, 2).unwrap();
    assert_eq!(scores.len(), 2 * 7, "2 layers x 7 targets");
    let q = scores.get("blocks/0/q/idx").unwrap();
    assert_eq!(q.len(), 64); // d_in of tiny-lm
    assert!(q.iter().all(|v| v.is_finite() && *v >= 0.0));
    assert!(q.iter().any(|v| *v > 0.0));
}

#[test]
fn different_seeds_give_different_selections_same_frozen_weights() {
    let _serial = serial();
    let r = require_artifacts!();
    let art = r.manifest.artifact("train_paca_tiny").unwrap();
    let s1 = init::init_state(art, 1, &Selection::Random).unwrap();
    let s2 = init::init_state(art, 2, &Selection::Random).unwrap();
    let idx_pos = art.state.iter()
        .position(|e| e.name == "blocks/0/q/idx").unwrap();
    assert_ne!(s1[idx_pos].as_i32(), s2[idx_pos].as_i32());
}

#[test]
fn vit_and_cnn_artifacts_execute() {
    let _serial = serial();
    let r = require_artifacts!();
    for name in ["train_paca_vit_tiny", "train_paca_cnn_tiny",
                 "train_full_cnn_tiny"] {
        let exe = r.load(name).unwrap();
        let art = exe.info.clone();
        let state = init::init_state(&art, 1, &Selection::Random)
            .unwrap();
        let mut inputs: Vec<xla::Literal> = state.iter()
            .map(|t| t.to_literal().unwrap()).collect();
        let imgs = paca::tensor::HostTensor::from_f32(
            &[art.batch, 3, 32, 32],
            vec![0.1; art.batch * 3 * 32 * 32]);
        let labels = paca::tensor::HostTensor::from_i32(
            &[art.batch], vec![1; art.batch]);
        inputs.push(imgs.to_literal().unwrap());
        inputs.push(labels.to_literal().unwrap());
        inputs.push(paca::tensor::HostTensor::scalar_f32(1e-3)
                    .to_literal().unwrap());
        let outs = exe.run(&inputs).unwrap();
        assert_eq!(outs.len(), art.outputs.len(), "{name}");
        let loss = outs[outs.len() - 2].get_first_element::<f32>()
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0, "{name}: loss {loss}");
    }
}

#[test]
fn trainer_rejects_eval_artifacts() {
    let _serial = serial();
    let mut c = cfg("eval_lm_tiny", 1);
    c.artifact = "eval_lm_tiny".into();
    let r = require_artifacts!();
    assert!(Trainer::new(&r, c).is_err());
}

#[test]
fn runtime_caches_compiled_executables() {
    let _serial = serial();
    let r = require_artifacts!();
    let a = r.load("train_paca_tiny").unwrap();
    let b = r.load("train_paca_tiny").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn merged_eval_matches_train_graph_loss() {
    let _serial = serial();
    // The merge module must be numerically faithful: the train graph's
    // reported loss at lr=0 on a batch must equal the eval graph's loss
    // on the same batch with host-merged weights.
    let r = require_artifacts!();
    for artifact in ["train_lora_tiny", "train_paca_tiny",
                     "train_moslora_tiny", "train_qpaca_tiny"] {
        let mut tr = Trainer::new(&r, cfg(artifact, 2)).unwrap();
        tr.run(false).unwrap();
        let eval = r.load("eval_lm_tiny").unwrap();
        let (b, s) = (eval.info.batch, eval.info.seq);
        let mut gen = paca::data::TokenGen::new(
            paca::data::Task::LmZipf, 512, 999);
        let batch = gen.train_batch(b, s);
        // train graph at lr=0 computes the loss at current params
        let (train_loss, _) = tr.dispatch(&batch, 0.0).unwrap();
        // eval graph with merged weights on the same batch
        let get = |name: &str| tr.state_tensor(name);
        let merged = paca::coordinator::merge::merged_state(
            &tr.exe.info, &eval.info.state, &get).unwrap();
        let mut inputs: Vec<xla::Literal> = merged.iter()
            .map(|t| t.to_literal().unwrap()).collect();
        inputs.push(batch.to_literal().unwrap());
        let outs = eval.run(&inputs).unwrap();
        let eval_loss = outs[0].get_first_element::<f32>().unwrap()
            as f64;
        let rel = (train_loss - eval_loss).abs()
            / train_loss.abs().max(1e-9);
        assert!(rel < 2e-4,
                "{artifact}: train {train_loss} vs merged-eval \
                 {eval_loss} (rel {rel})");
    }
}
