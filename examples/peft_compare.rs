//! Side-by-side comparison of all seven PEFT methods on the same task
//! and model — the paper's core comparison matrix in miniature.
//!
//!     cargo run --release --example peft_compare -- [steps]

use anyhow::Result;
use paca::config::TrainConfig;
use paca::coordinator::Trainer;
use paca::metrics::{fmt_params, Table};
use paca::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1)
        .map(|s| s.parse()).transpose()?.unwrap_or(50);
    let rt = Runtime::new(&paca::default_artifacts_dir())?;

    let mut table = Table::new(&["Method", "Rank", "Trainable", "s/step",
                                 "loss start", "loss end",
                                 "held-out acc"]);
    for (method, artifact, rank) in [
        ("full", "train_full_tiny", 0),
        ("lora", "train_lora_tiny", 8),
        ("dora", "train_dora_tiny", 8),
        ("moslora", "train_moslora_tiny", 8),
        ("paca", "train_paca_tiny", 8),
        ("paca", "train_paca_tiny_r16", 16),
        ("qlora", "train_qlora_tiny", 8),
        ("qpaca", "train_qpaca_tiny", 8),
    ] {
        let mut cfg = TrainConfig::default();
        cfg.artifact = artifact.into();
        cfg.task = "instr".into();
        cfg.steps = steps;
        cfg.warmup_steps = (steps / 10).max(1);
        cfg.peak_lr = if method == "full" { 5e-4 } else { 2e-3 };
        let mut tr = Trainer::new(&rt, cfg)?;
        let t0 = std::time::Instant::now();
        tr.run(false)?;
        let per_step = t0.elapsed().as_secs_f64() / steps as f64;
        let ev = tr.evaluate(2)?;
        table.row(&[method.into(), rank.to_string(),
                    fmt_params(tr.info().trainable_params as f64),
                    format!("{:.4}", per_step),
                    format!("{:.3}", tr.curve.loss[0]),
                    format!("{:.3}", tr.curve.tail_mean(5)),
                    format!("{:.3}", ev.mean_acc())]);
        println!("{method:8} r{rank:<3} done");
    }
    println!("\n{}", table.render());
    Ok(())
}
