//! Quickstart: fine-tune a tiny LLaMA-style model with PaCA in ~30
//! seconds on CPU.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the whole public API surface: runtime, config, trainer,
//! per-category eval, and checkpointing.

use anyhow::Result;
use paca::config::TrainConfig;
use paca::coordinator::Trainer;
use paca::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new(&paca::default_artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    let mut cfg = TrainConfig::default();
    cfg.artifact = "train_paca_tiny".into();
    cfg.task = "lm-zipf".into();
    cfg.steps = 40;
    cfg.warmup_steps = 4;
    cfg.peak_lr = 2e-3;
    cfg.log_every = 5;

    let mut trainer = Trainer::new(&rt, cfg)?;
    println!("model {} | method {} | rank {} | {} trainable params",
             trainer.info().model, trainer.info().method,
             trainer.info().rank, trainer.info().trainable_params);

    trainer.run(true)?;

    let first = trainer.curve.loss.first().copied().unwrap_or(0.0);
    let last = trainer.curve.tail_mean(5);
    println!("\nloss: {first:.3} -> {last:.3} over {} steps",
             trainer.step);
    assert!(last < first, "training must reduce the loss");

    let eval = trainer.evaluate(4)?;
    println!("held-out: loss {:.3}, token accuracy {:.3}",
             eval.mean_loss(), eval.mean_acc());

    let ckpt = std::env::temp_dir().join("paca-quickstart.ckpt");
    trainer.save_checkpoint(&ckpt)?;
    println!("checkpoint written to {}", ckpt.display());
    Ok(())
}
