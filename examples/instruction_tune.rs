//! Instruction-tuning scenario (paper §4.2 in miniature): fine-tune the
//! small-lm preset on the synthetic instruction corpus with PaCA vs
//! LoRA, reporting per-category MT-Bench-style score proxies and the
//! training-efficiency delta.
//!
//!     cargo run --release --example instruction_tune -- [steps]

use anyhow::Result;
use paca::config::{preset, SchedKind};
use paca::coordinator::Trainer;
use paca::data::MTBENCH_CATEGORIES;
use paca::metrics::Table;
use paca::runtime::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1)
        .map(|s| s.parse()).transpose()?.unwrap_or(80);
    let rt = Runtime::new(&paca::default_artifacts_dir())?;

    let mut header = vec!["Method", "s/step", "Avg"];
    header.extend(MTBENCH_CATEGORIES);
    let mut table = Table::new(&header);

    let mut paca_per_step = 0.0;
    let mut lora_per_step = 0.0;
    for (method, artifact) in [("paca", "train_paca_small"),
                               ("lora", "train_lora_small")] {
        let mut cfg = preset("instr")?;
        cfg.artifact = artifact.into();
        cfg.steps = steps;
        cfg.warmup_steps = (steps / 10).max(1);
        cfg.sched = SchedKind::Linear;
        cfg.peak_lr = 1.5e-3;
        let mut tr = Trainer::new(&rt, cfg)?;
        println!("training {method} ({artifact}) for {steps} steps…");
        let t0 = std::time::Instant::now();
        tr.run(false)?;
        let per_step = t0.elapsed().as_secs_f64() / steps as f64;
        if method == "paca" {
            paca_per_step = per_step;
        } else {
            lora_per_step = per_step;
        }
        let ev = tr.evaluate(4)?;
        let scores = ev.scores();
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        let mut row = vec![method.to_string(),
                           format!("{:.3}", per_step),
                           format!("{:.2}", avg)];
        row.extend(scores.iter().map(|s| format!("{:.1}", s)));
        table.row(&row);
        println!("  loss {:.3} -> {:.3}, mean score {avg:.2}",
                 tr.curve.loss[0], tr.curve.tail_mean(5));
    }
    println!("\n{}", table.render());
    println!("PaCA step-time vs LoRA: {:+.1}% (paper: -19% at 8B scale)",
             (paca_per_step / lora_per_step - 1.0) * 100.0);
    Ok(())
}
