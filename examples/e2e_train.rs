//! End-to-end driver (DESIGN.md §5, EXPERIMENTS.md §E2E): fine-tune the
//! ~110M-parameter `large-lm` transformer with PaCA for a few hundred
//! steps on the synthetic instruction corpus, proving all three layers
//! compose: Pallas-validated kernels → AOT-lowered JAX train graph →
//! rust coordinator on the PJRT CPU client.
//!
//!     cargo run --release --example e2e_train -- [steps] [artifact]
//!
//! Defaults: 300 steps of train_paca_large (batch 4, seq 128). Writes
//! the loss curve to e2e_loss_curve.csv and a checkpoint next to it.

use std::time::Instant;

use anyhow::Result;
use paca::config::{SchedKind, TrainConfig};
use paca::coordinator::Trainer;
use paca::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?
        .unwrap_or(300);
    let artifact = args.get(1).cloned()
        .unwrap_or_else(|| "train_paca_large".to_string());

    let rt = Runtime::new(&paca::default_artifacts_dir())?;
    let mut cfg = TrainConfig::default();
    cfg.artifact = artifact;
    cfg.task = "instr".into();
    cfg.steps = steps;
    cfg.warmup_steps = (steps / 20).max(5);
    cfg.sched = SchedKind::Cosine;
    cfg.peak_lr = 1e-3;
    cfg.log_every = 10;
    cfg.eval_every = 0;

    let t0 = Instant::now();
    let mut trainer = Trainer::new(&rt, cfg)?;
    let model = rt.manifest.model(&trainer.info().model)?;
    let compile_s = t0.elapsed().as_secs_f64();
    println!("e2e: {} ({} params, method {}, rank {}) — compiled + \
              initialized in {compile_s:.1}s",
             model.name,
             paca::metrics::fmt_params(model.n_params() as f64),
             trainer.info().method, trainer.info().rank);
    println!("trainable: {} params ({:.3}% of model)",
             paca::metrics::fmt_params(
                 trainer.info().trainable_params as f64),
             100.0 * trainer.info().trainable_params as f64
                 / model.n_params() as f64);

    let (b, s) = trainer.batch_geometry();
    let train_t0 = Instant::now();
    trainer.run(true)?;
    let train_s = train_t0.elapsed().as_secs_f64();
    let toks_per_s = (trainer.step * b * s) as f64 / train_s;

    println!("\n=== e2e summary ===");
    println!("steps: {}   wall: {:.1}s   {:.3} s/step   {:.0} tok/s   \
              {:.2} seq/s",
             trainer.step, train_s, train_s / trainer.step as f64,
             toks_per_s, (trainer.step * b) as f64 / train_s);
    println!("timers: {}", trainer.timers.report());
    let first = trainer.curve.loss.first().copied().unwrap_or(0.0);
    println!("loss: {:.4} -> {:.4} (tail-5 mean)", first,
             trainer.curve.tail_mean(5));

    // Loss curve snapshot (every ~10th point) for EXPERIMENTS.md.
    print!("curve:");
    let n = trainer.curve.steps.len();
    for i in (0..n).step_by((n / 12).max(1)) {
        print!(" {}:{:.3}", trainer.curve.steps[i],
               trainer.curve.loss[i]);
    }
    println!(" {}:{:.3}", trainer.curve.steps[n - 1],
             trainer.curve.loss[n - 1]);

    std::fs::write("e2e_loss_curve.csv", trainer.curve.to_csv())?;
    trainer.save_checkpoint(std::path::Path::new("e2e_model.ckpt"))?;
    println!("wrote e2e_loss_curve.csv + e2e_model.ckpt");

    let ev = trainer.evaluate(4)?;
    println!("\nheld-out per-category eval:");
    for (c, (l, a)) in ev.categories.iter()
        .zip(ev.loss.iter().zip(&ev.acc))
    {
        println!("  {:<9} loss {:.4}  acc {:.3}", c, l, a);
    }
    println!("  mean      loss {:.4}  acc {:.3}", ev.mean_loss(),
             ev.mean_acc());
    assert!(trainer.curve.tail_mean(5) < first,
            "e2e training must reduce the loss");
    Ok(())
}
